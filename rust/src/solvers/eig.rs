//! Exact Kronecker solver from per-factor eigendecompositions.
//!
//! On a fully-observed grid the LKGP system is `K_SS (x) K_TT + sigma2
//! I` with no projection, and per-factor eigendecompositions `K_SS =
//! Q_S L_S Q_S^T`, `K_TT = Q_T L_T Q_T^T` diagonalize it exactly:
//!
//! ```text
//! (K_SS (x) K_TT + sigma2 I)^{-1}
//!     = (Q_S (x) Q_T) (L_S (x) L_T + sigma2 I)^{-1} (Q_S (x) Q_T)^T
//! ```
//!
//! so a solve is two small GEMM sandwiches plus an elementwise divide —
//! `O(p^3 + q^3)` once per hyperparameter setting, then `O(p^2 q + p
//! q^2)` per right-hand side, with zero CG iterations. The same
//! identity with `(L + sigma2 I)^{1/2}` gives the exact matrix square
//! root used to validate pathwise conditioning.
//!
//! Determinism: the factorization (`linalg::eig`) is sequential and the
//! applies reuse `KronOp::apply_batch`, whose parallel schedule is
//! bit-invariant in `LKGP_THREADS`, so this path honors the crate-wide
//! reproducibility contract.

use crate::kron::KronOp;
use crate::linalg::eig::EigError;
use crate::linalg::{sym_eig, Matrix, Scalar};

/// Typed failure of [`EigSolver::try_new`].
#[derive(Clone, Debug)]
pub enum EigSolveError {
    /// Eigendecomposition of one Gram factor failed.
    Factor {
        /// Which factor ("K_SS" or "K_TT").
        factor: &'static str,
        /// The underlying eigensolver failure.
        source: EigError,
    },
    /// A combined system eigenvalue `l_S[i] l_T[j] + sigma2` is not
    /// finite and positive, so the system cannot be inverted spectrally.
    BadEigenvalue {
        /// Flat index `i*q + j` of the offending eigenvalue.
        index: usize,
        /// The offending value.
        value: f64,
    },
}

impl std::fmt::Display for EigSolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EigSolveError::Factor { factor, source } => {
                write!(f, "eigendecomposition of {factor} failed: {source}")
            }
            EigSolveError::BadEigenvalue { index, value } => {
                write!(f, "system eigenvalue {index} = {value} is not finite and positive")
            }
        }
    }
}

impl std::error::Error for EigSolveError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EigSolveError::Factor { source, .. } => Some(source),
            EigSolveError::BadEigenvalue { .. } => None,
        }
    }
}

/// Direct solver for `(K_SS (x) K_TT + sigma2 I) x = b` on the full
/// latent grid, factored once per hyperparameter setting.
#[derive(Clone, Debug)]
pub struct EigSolver {
    /// The original Gram factors (kept for true residual checks).
    pub op: KronOp<f64>,
    /// `(Q_S, Q_T)` — maps spectral coordinates back to the grid.
    pub lift: KronOp<f64>,
    /// `(Q_S^T, Q_T^T)` — maps grid vectors to spectral coordinates.
    pub proj: KronOp<f64>,
    /// System eigenvalues `evals[i*q + j] = l_S[i] * l_T[j] + sigma2`,
    /// all finite and strictly positive.
    pub evals: Vec<f64>,
    /// The noise variance folded into `evals`.
    pub sigma2: f64,
}

impl EigSolver {
    /// Eigendecompose both Gram factors and assemble the spectral
    /// solver. Fails typed when a factor decomposition fails or any
    /// combined eigenvalue is non-finite or non-positive (e.g. a
    /// rank-deficient kernel with `sigma2 == 0`).
    pub fn try_new(
        kss: &Matrix<f64>,
        ktt: &Matrix<f64>,
        sigma2: f64,
    ) -> Result<Self, EigSolveError> {
        let es = sym_eig(kss)
            .map_err(|source| EigSolveError::Factor { factor: "K_SS", source })?;
        let et = sym_eig(ktt)
            .map_err(|source| EigSolveError::Factor { factor: "K_TT", source })?;
        let (p, q) = (kss.rows, ktt.rows);
        let mut evals = Vec::with_capacity(p * q);
        for i in 0..p {
            for j in 0..q {
                let v = es.values[i] * et.values[j] + sigma2;
                if !v.is_finite() || v <= 0.0 {
                    return Err(EigSolveError::BadEigenvalue { index: i * q + j, value: v });
                }
                evals.push(v);
            }
        }
        Ok(EigSolver {
            op: KronOp::new(kss.clone(), ktt.clone()),
            lift: KronOp::new(es.vectors.clone(), et.vectors.clone()),
            proj: KronOp::new(es.vectors.transpose(), et.vectors.transpose()),
            evals,
            sigma2,
        })
    }

    /// Number of spatial points p.
    pub fn p(&self) -> usize {
        self.op.p()
    }

    /// Number of time steps / tasks q.
    pub fn q(&self) -> usize {
        self.op.q()
    }

    /// Grid dimension p*q.
    pub fn dim(&self) -> usize {
        self.op.dim()
    }

    /// Solve the system for every row of `b` exactly, in f64 regardless
    /// of `T`. Returns the solutions together with the true per-row
    /// relative residuals `||b - A x|| / ||b||` (computed against the
    /// original factors, not the spectral form, so roundoff in the
    /// decomposition is measured honestly — typically ~1e-14).
    pub fn solve_batch<T: Scalar>(&self, b: &Matrix<T>) -> (Matrix<T>, Vec<f64>) {
        let b64: Matrix<f64> = b.cast();
        let mut u = self.proj.apply_batch(&b64);
        let cols = u.cols;
        crate::par::par_chunks_mut_cheap("eig.scale", &mut u.data, cols.max(1), |_, row| {
            for (x, ev) in row.iter_mut().zip(&self.evals) {
                *x /= *ev;
            }
        });
        let x = self.lift.apply_batch(&u);
        let ax = self.op.apply_batch(&x);
        let mut rels = Vec::with_capacity(b.rows);
        for r in 0..b.rows {
            let (br, xr, ar) = (b64.row(r), x.row(r), ax.row(r));
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..cols {
                let resid = br[i] - (ar[i] + self.sigma2 * xr[i]);
                num += resid * resid;
                den += br[i] * br[i];
            }
            rels.push(num.sqrt() / den.sqrt().max(1e-300));
        }
        (x.cast(), rels)
    }

    /// Apply the exact symmetric matrix square root `(K_SS (x) K_TT +
    /// sigma2 I)^{1/2}` to every row of `z` (pathwise-conditioning
    /// prior draws: `sqrt_apply(z)` has the system as its covariance
    /// for standard-normal `z`).
    pub fn sqrt_apply<T: Scalar>(&self, z: &Matrix<T>) -> Matrix<T> {
        let z64: Matrix<f64> = z.cast();
        let mut u = self.proj.apply_batch(&z64);
        let cols = u.cols;
        crate::par::par_chunks_mut_cheap("eig.sqrt_scale", &mut u.data, cols.max(1), |_, row| {
            for (x, ev) in row.iter_mut().zip(&self.evals) {
                *x *= ev.sqrt();
            }
        });
        self.lift.apply_batch(&u).cast()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_eig_solve_matches_dense_cholesky() {
        prop_check("eig-solve-vs-chol", 907, 15, |g| {
            let (p, q) = (g.size(1, 7), g.size(1, 7));
            let kss = Matrix::from_vec(p, p, g.spd(p));
            let ktt = Matrix::from_vec(q, q, g.spd(q));
            let sigma2 = g.f64_in(0.01, 1.0);
            let es = EigSolver::try_new(&kss, &ktt, sigma2).map_err(|e| e.to_string())?;
            let n = p * q;
            let b = Matrix::from_vec(2, n, g.vec_normal(2 * n));
            let (x, rels) = es.solve_batch(&b);
            for (r, rel) in rels.iter().enumerate() {
                if *rel > 1e-10 {
                    return Err(format!("row {r} residual {rel}"));
                }
            }
            // dense reference: Cholesky of K_SS (x) K_TT + sigma2 I
            let mut dense = es.op.dense();
            dense.add_diag(sigma2);
            let ch = cholesky(&dense).ok_or("dense cholesky failed")?;
            for r in 0..2 {
                let want = ch.solve(b.row(r));
                assert_close(x.row(r), &want, 1e-7)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sqrt_apply_squares_to_the_system() {
        prop_check("eig-sqrt", 911, 10, |g| {
            let (p, q) = (g.size(1, 6), g.size(1, 6));
            let kss = Matrix::from_vec(p, p, g.spd(p));
            let ktt = Matrix::from_vec(q, q, g.spd(q));
            let sigma2 = g.f64_in(0.01, 0.5);
            let es = EigSolver::try_new(&kss, &ktt, sigma2).map_err(|e| e.to_string())?;
            let n = p * q;
            let z = Matrix::from_vec(1, n, g.vec_normal(n));
            // S (S z) == (K + sigma2 I) z for the symmetric root S
            let got = es.sqrt_apply(&es.sqrt_apply(&z));
            let mut want = es.op.apply_batch(&z);
            for (w, zi) in want.row_mut(0).iter_mut().zip(z.row(0)) {
                *w += sigma2 * zi;
            }
            assert_close(got.row(0), want.row(0), 1e-8)
        });
    }

    #[test]
    fn construction_failures_are_typed() {
        let mut bad = Matrix::zeros(2, 2);
        bad[(1, 1)] = f64::INFINITY;
        let ok = Matrix::from_fn(2, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        match EigSolver::try_new(&bad, &ok, 0.1) {
            Err(EigSolveError::Factor { factor: "K_SS", .. }) => {}
            other => panic!("expected Factor error, got {other:?}"),
        }
        // rank-deficient kernel with zero noise: zero system eigenvalue
        let zero = Matrix::zeros(2, 2);
        match EigSolver::try_new(&zero, &ok, 0.0) {
            Err(EigSolveError::BadEigenvalue { .. }) => {}
            other => panic!("expected BadEigenvalue, got {other:?}"),
        }
    }

    #[test]
    fn f32_rhs_round_trips_through_f64() {
        let mut g = crate::util::testing::Gen { rng: crate::util::rng::Rng::new(17) };
        let (p, q) = (4, 3);
        let kss = Matrix::from_vec(p, p, g.spd(p));
        let ktt = Matrix::from_vec(q, q, g.spd(q));
        let es = EigSolver::try_new(&kss, &ktt, 0.2).expect("solver");
        let b32: Matrix<f32> =
            Matrix::from_vec(1, p * q, g.vec_normal(p * q)).cast();
        let (x32, rels) = es.solve_batch(&b32);
        assert!(rels[0] < 1e-10, "residual {}", rels[0]);
        let (x64, _) = es.solve_batch(&b32.cast::<f64>());
        for (a, b) in x32.row(0).iter().zip(x64.row(0)) {
            assert!((f64::from(*a) - b).abs() < 1e-4);
        }
    }
}
