//! Stochastic-gradient linear solver (Lin et al. 2023 / 2024a, "SGD for
//! GPs done right" — cited in paper Sec. 2).
//!
//! Solves (K + sigma2 I) x = b by minimizing the convex quadratic
//! 1/2 x^T A x - x^T b with heavy-ball gradient descent and Polyak
//! iterate averaging. Deterministic full gradients here (the stochastic
//! variant subsamples rows; at this testbed's scale the full gradient
//! IS the MVM the paper counts), with step size from power-iteration
//! estimates of the largest eigenvalue.

use crate::linalg::{Matrix, Scalar};

use super::cg::{BatchedOp, CgStats};

/// Stopping criteria and dynamics for the SGD solver.
pub struct SgdOptions {
    /// Gradient-step cap.
    pub max_iters: usize,
    /// Relative residual tolerance.
    pub tol: f64,
    /// Heavy-ball momentum coefficient.
    pub momentum: f64,
    /// iterate-averaging window fraction (tail averaging)
    pub avg_frac: f64,
}

impl Default for SgdOptions {
    fn default() -> Self {
        SgdOptions { max_iters: 400, tol: 1e-2, momentum: 0.9, avg_frac: 0.3 }
    }
}

/// Estimate the largest eigenvalue of A by power iteration (for the
/// step size 1/L).
fn power_iter_lmax<T: Scalar>(op: &mut impl BatchedOp<T>, iters: usize) -> f64 {
    let n = op.dim();
    let mut v = Matrix::<T>::zeros(1, n);
    for (i, x) in v.row_mut(0).iter_mut().enumerate() {
        *x = T::from_f64(((i * 2654435761) % 97) as f64 / 97.0 - 0.5);
    }
    let mut lmax = 1.0;
    for _ in 0..iters {
        let av = op.apply_batch(&v);
        let norm: f64 =
            av.row(0).iter().map(|x| x.to_f64() * x.to_f64()).sum::<f64>().sqrt().max(1e-300);
        lmax = norm
            / v.row(0)
                .iter()
                .map(|x| x.to_f64() * x.to_f64())
                .sum::<f64>()
                .sqrt()
                .max(1e-300);
        for (vi, avi) in v.row_mut(0).iter_mut().zip(av.row(0)) {
            *vi = T::from_f64(avi.to_f64() / norm);
        }
    }
    lmax.max(1e-12)
}

/// Solve A X = B with heavy-ball SGD + tail averaging.
pub fn solve_sgd<T: Scalar>(
    op: &mut impl BatchedOp<T>,
    b: &Matrix<T>,
    opts: &SgdOptions,
) -> (Matrix<T>, CgStats) {
    let n = op.dim();
    assert_eq!(b.cols, n);
    let nsys = b.rows;
    let mut stats = CgStats::default();
    let lmax = power_iter_lmax(op, 12);
    stats.mvm_count += 12;
    // heavy-ball: lr tuned for [mu, L] with unknown mu; safe choice
    let lr = 1.0 / lmax * (1.0 - opts.momentum);

    let mut x = Matrix::<T>::zeros(nsys, n);
    let mut vprev = Matrix::<T>::zeros(nsys, n);
    let mut avg = Matrix::<T>::zeros(nsys, n);
    let mut avg_count = 0usize;
    let avg_start = ((1.0 - opts.avg_frac) * opts.max_iters as f64) as usize;
    let b_norms: Vec<f64> = (0..nsys)
        .map(|s| {
            b.row(s).iter().map(|v| v.to_f64() * v.to_f64()).sum::<f64>().sqrt().max(1e-300)
        })
        .collect();

    for it in 0..opts.max_iters {
        let ax = op.apply_batch(&x);
        stats.mvm_count += 1;
        // grad = A x - b ; residual r = -grad
        let mut worst = 0.0f64;
        for s in 0..nsys {
            let mut racc = 0.0;
            for ((xi, vp), (axi, bi)) in x
                .row_mut(s)
                .iter_mut()
                .zip(vprev.row_mut(s).iter_mut())
                .zip(ax.row(s).iter().zip(b.row(s)))
            {
                let g = axi.to_f64() - bi.to_f64();
                racc += g * g;
                let vnew = opts.momentum * vp.to_f64() - lr * g;
                *vp = T::from_f64(vnew);
                *xi += T::from_f64(vnew);
            }
            worst = worst.max(racc.sqrt() / b_norms[s]);
        }
        stats.iters = it + 1;
        stats.rel_residuals = vec![worst];
        if it >= avg_start {
            for s in 0..nsys {
                for (a, xi) in avg.row_mut(s).iter_mut().zip(x.row(s)) {
                    *a += *xi;
                }
            }
            avg_count += 1;
        }
        if worst < opts.tol {
            stats.converged = true;
            break;
        }
    }
    if avg_count > 1 && !stats.converged {
        // tail-averaged iterate (variance reduction of the SGD papers)
        let inv = T::from_f64(1.0 / avg_count as f64);
        for s in 0..nsys {
            for (xi, a) in x.row_mut(s).iter_mut().zip(avg.row(s)) {
                *xi = *a * inv;
            }
        }
    }
    (x, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::cg::DenseOp;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_solves_well_conditioned_systems() {
        prop_check("sgd-solves", 223, 10, |g| {
            let n = g.size(2, 25);
            let mut a = Matrix::from_vec(n, n, g.spd(n));
            a.add_diag(1.0); // keep conditioning benign for SGD
            let b = Matrix::from_vec(1, n, g.vec_normal(n));
            let (x, stats) = solve_sgd(
                &mut DenseOp(&a),
                &b,
                &SgdOptions { max_iters: 4000, tol: 1e-6, ..SgdOptions::default() },
            );
            if !stats.converged {
                return Err(format!("not converged: {:?}", stats.rel_residuals));
            }
            assert_close(&a.matvec(x.row(0)), b.row(0), 1e-4)
        });
    }

    #[test]
    fn momentum_helps_on_ill_conditioned_system() {
        // heavy-ball's advantage shows on spread spectra: diag system
        // with condition number 1e3. (On well-conditioned systems the
        // (1-m)/L step makes it slower — expected.)
        let n = 40;
        let a = Matrix::from_fn(n, n, |i, j| {
            if i == j {
                1.0 + 999.0 * (i as f64 / (n - 1) as f64)
            } else {
                0.0
            }
        });
        let b = Matrix::from_vec(1, n, vec![1.0; n]);
        let run = |mom: f64| {
            let (_, s) = solve_sgd(
                &mut DenseOp(&a),
                &b,
                &SgdOptions { max_iters: 20000, tol: 1e-6, momentum: mom, avg_frac: 0.0 },
            );
            (s.converged, s.iters)
        };
        let (c_mom, it_mom) = run(0.95);
        let (c_plain, it_plain) = run(0.0);
        assert!(c_mom, "momentum run failed");
        assert!(
            !c_plain || it_mom < it_plain,
            "momentum {it_mom} !< plain {it_plain}"
        );
    }
}
