//! CG preconditioners.
//!
//! * `Identity` — plain CG.
//! * `Jacobi` — diagonal scaling.
//! * `LowRankPlusNoise` — the paper's pivoted-Cholesky preconditioner
//!   (Appendix C, rank 100): M = L L^T + sigma2 I with L a rank-r
//!   pivoted Cholesky factor of the kernel matrix; M^{-1} applied via
//!   the Woodbury identity in O(n r) per vector after an O(r^3) setup.
//! * `KronEig` — the exact inverse of the *unmasked* latent system
//!   `(Q_S (x) Q_T)(L_S (x) L_T + sigma2 I)^{-1}(Q_S (x) Q_T)^T` from
//!   per-factor eigendecompositions; a near-perfect preconditioner for
//!   the masked system when few grid cells are missing.

use crate::kron::KronOp;
use crate::linalg::chol::{cholesky, Cholesky};
use crate::linalg::{Matrix, Scalar};
use crate::solvers::eig::{EigSolveError, EigSolver};
use crate::util::failpoint::{self, FaultAction, InjectedFault};

/// Typed failures while *constructing* a preconditioner.
///
/// Construction failures are recoverable: the policy layer in
/// `gp::lkgp` falls back pivoted Cholesky → Jacobi → identity, so these
/// errors are data for that chain rather than a reason to abort a fit.
#[derive(Clone, Debug)]
pub enum PrecondError {
    /// The Woodbury capacitance matrix `sigma2 I + L^T L` was not
    /// positive definite (Cholesky failed).
    CapacitanceNotPd {
        /// Rank of the offending low-rank factor.
        rank: usize,
    },
    /// A system diagonal entry was NaN/Inf, so no diagonal-based
    /// preconditioner can be formed from it.
    NonFiniteDiag {
        /// Index of the first non-finite entry.
        index: usize,
        /// The offending value.
        value: f64,
    },
    /// A system diagonal entry was zero or negative — inverting it
    /// would produce a huge (or indefinite) scale, not a precondition.
    NonPositiveDiag {
        /// Index of the first non-positive entry.
        index: usize,
        /// The offending value.
        value: f64,
    },
    /// The per-factor eigendecomposition behind `KronEig` failed
    /// (factor decomposition error or a bad system eigenvalue).
    KronEig(EigSolveError),
    /// A `precond_build` failpoint fired (fault-injection harness).
    Injected(InjectedFault),
}

impl std::fmt::Display for PrecondError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrecondError::CapacitanceNotPd { rank } => {
                write!(
                    f,
                    "preconditioner capacitance matrix (rank {rank}) is not positive definite"
                )
            }
            PrecondError::NonFiniteDiag { index, value } => {
                write!(f, "system diagonal entry {index} is non-finite ({value})")
            }
            PrecondError::NonPositiveDiag { index, value } => {
                write!(f, "system diagonal entry {index} is not positive ({value})")
            }
            PrecondError::KronEig(e) => {
                write!(f, "latent-grid eigendecomposition preconditioner failed: {e}")
            }
            PrecondError::Injected(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PrecondError {}

/// A CG preconditioner `M ~ A` applied as `z = M^{-1} r` per iteration.
pub enum Preconditioner<T: Scalar> {
    /// No preconditioning (M = I).
    Identity,
    /// Diagonal scaling.
    Jacobi {
        /// Reciprocal of the system diagonal.
        inv_diag: Vec<T>,
    },
    /// The paper's pivoted-Cholesky preconditioner
    /// `M = L L^T + sigma2 I`, applied via the Woodbury identity.
    LowRankPlusNoise {
        /// Rank-r pivoted Cholesky factor (n x r).
        l: Matrix<T>,
        /// Observation-noise variance on the diagonal.
        sigma2: T,
        /// Cholesky of the r x r capacitance `sigma2 I + L^T L`.
        cap_chol: Cholesky<T>,
    },
    /// Exact inverse of the unmasked latent system from per-factor
    /// eigendecompositions: `M^{-1} = (Q_S (x) Q_T) diag(inv_evals)
    /// (Q_S (x) Q_T)^T`. SPD by construction (all system eigenvalues
    /// are validated finite and positive at build time).
    KronEig {
        /// `(Q_S, Q_T)` — spectral coordinates back to the grid.
        lift: KronOp<T>,
        /// `(Q_S^T, Q_T^T)` — grid vectors to spectral coordinates.
        proj: KronOp<T>,
        /// Reciprocal system eigenvalues `1 / (l_S[i] l_T[j] + sigma2)`.
        inv_evals: Vec<T>,
    },
}

impl<T: Scalar> Preconditioner<T> {
    /// Jacobi preconditioner from the system diagonal. Panics on a
    /// zero, negative, or non-finite diagonal; prefer
    /// [`Preconditioner::try_jacobi`] where a fallback exists.
    pub fn jacobi(diag: &[f64]) -> Self {
        match Self::try_jacobi(diag) {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`Preconditioner::jacobi`]: validates the diagonal is
    /// finite (a NaN would otherwise slip through the `max` clamp and
    /// produce a finite-but-meaningless scale) **and strictly
    /// positive** (a zero entry — e.g. a masked cell of a zero-noise
    /// system — would invert to a huge scale that wrecks CG instead of
    /// helping it) before building the clamped reciprocal. Degenerate
    /// diagonals become typed errors so the `gp::lkgp` fallback chain
    /// can drop to the identity instead of aborting the fit.
    pub fn try_jacobi(diag: &[f64]) -> Result<Self, PrecondError> {
        if let Some((index, &value)) = diag.iter().enumerate().find(|(_, v)| !v.is_finite()) {
            return Err(PrecondError::NonFiniteDiag { index, value });
        }
        if let Some((index, &value)) = diag.iter().enumerate().find(|(_, v)| **v <= 0.0) {
            return Err(PrecondError::NonPositiveDiag { index, value });
        }
        Ok(Preconditioner::Jacobi {
            inv_diag: diag.iter().map(|&d| T::from_f64(1.0 / d.max(1e-12))).collect(),
        })
    }

    /// Latent-grid eigendecomposition preconditioner: the exact inverse
    /// of `K_SS (x) K_TT + sigma2 I` (the unmasked system), applied on
    /// the padded grid. Under light masking the masked system differs
    /// from this by a low-rank perturbation, so CG converges in a
    /// handful of iterations. Fails typed when a factor
    /// eigendecomposition fails or any system eigenvalue is non-finite
    /// or non-positive; honours the `precond_build` failpoint like the
    /// pivoted-Cholesky builder.
    pub fn try_kron_eig(
        kss: &Matrix<f64>,
        ktt: &Matrix<f64>,
        sigma2: f64,
    ) -> Result<Self, PrecondError> {
        if let Some(action) = failpoint::check("precond_build") {
            if action == FaultAction::Error {
                return Err(PrecondError::Injected(InjectedFault {
                    site: "precond_build".into(),
                    action,
                }));
            }
        }
        let es = EigSolver::try_new(kss, ktt, sigma2).map_err(PrecondError::KronEig)?;
        Ok(Preconditioner::KronEig {
            lift: KronOp::new(es.lift.kss.cast(), es.lift.ktt.cast()),
            proj: KronOp::new(es.proj.kss.cast(), es.proj.ktt.cast()),
            inv_evals: es.evals.iter().map(|&v| T::from_f64(1.0 / v)).collect(),
        })
    }

    /// Build the Woodbury form for M = L L^T + sigma2 I:
    /// M^{-1} = (1/s2) [ I - L (s2 I_r + L^T L)^{-1} L^T ].
    /// Panics if the capacitance matrix is not PD; prefer
    /// [`Preconditioner::try_low_rank`] where a fallback exists.
    pub fn low_rank(l: Matrix<T>, sigma2: f64) -> Self {
        match Self::try_low_rank(l, sigma2) {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`Preconditioner::low_rank`]: a non-PD capacitance
    /// matrix becomes a typed [`PrecondError`] instead of a panic.
    pub fn try_low_rank(l: Matrix<T>, sigma2: f64) -> Result<Self, PrecondError> {
        let r = l.cols;
        let mut cap = l.transpose().matmul(&l); // r x r
        for i in 0..r {
            cap[(i, i)] += T::from_f64(sigma2);
        }
        let cap_chol =
            cholesky(&cap).ok_or(PrecondError::CapacitanceNotPd { rank: r })?;
        Ok(Preconditioner::LowRankPlusNoise { l, sigma2: T::from_f64(sigma2), cap_chol })
    }

    /// Build from a lazily-evaluated kernel: greedy pivoted Cholesky
    /// using only the kernel diagonal and single columns (never the full
    /// matrix) — O(n r^2) work, O(n r) memory.
    ///
    /// Pivot selection is inherently sequential, but each column update
    /// sweeps n rows; those rows are split across the `crate::par`
    /// worker pool under the **stealing schedule** — rows whose pivots
    /// were already consumed short-circuit, so chunk cost is ragged and
    /// the shared-cursor assignment keeps workers balanced. Each row
    /// block is still written by exactly one worker with a fixed
    /// per-row reduction order, so the factor is bit-identical for any
    /// thread count. The `col` oracle itself typically parallelizes
    /// internally too (e.g. `MaskedKronSystem::kernel_col`).
    pub fn pivoted_from_columns(
        diag_no_noise: Vec<f64>,
        col: impl Fn(usize) -> Vec<T>,
        rank: usize,
        sigma2: f64,
    ) -> Self {
        match Self::try_pivoted_from_columns(diag_no_noise, col, rank, sigma2) {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible [`Preconditioner::pivoted_from_columns`]: validates the
    /// input diagonal, converts a non-PD capacitance into a typed
    /// [`PrecondError`], and honours the `precond_build` failpoint so
    /// the fallback chain in `gp::lkgp` is testable.
    pub fn try_pivoted_from_columns(
        diag_no_noise: Vec<f64>,
        col: impl Fn(usize) -> Vec<T>,
        rank: usize,
        sigma2: f64,
    ) -> Result<Self, PrecondError> {
        if let Some(action) = failpoint::check("precond_build") {
            if action == FaultAction::Error {
                return Err(PrecondError::Injected(InjectedFault {
                    site: "precond_build".into(),
                    action,
                }));
            }
        }
        if let Some((index, &value)) =
            diag_no_noise.iter().enumerate().find(|(_, v)| !v.is_finite())
        {
            return Err(PrecondError::NonFiniteDiag { index, value });
        }
        // 128 rows per chunk (down from the spawn-era 256): cheaper
        // pool dispatch makes finer stealing granularity a net win for
        // the ragged later columns. Chunk boundaries are shape-only, so
        // the choice cannot affect output bits.
        const ROW_BLOCK: usize = 128;
        let n = diag_no_noise.len();
        let rank = rank.min(n);
        let mut d = diag_no_noise;
        let max0 = d.iter().cloned().fold(0.0, f64::max).max(1e-300);
        let mut l = Matrix::<T>::zeros(n, rank);
        let mut used = vec![false; n];
        let mut k_eff = 0;
        for k in 0..rank {
            let Some((piv, &dmax)) = d
                .iter()
                .enumerate()
                .filter(|(i, _)| !used[*i])
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            else {
                break;
            };
            if dmax < 1e-8 * max0 || dmax <= 0.0 {
                break;
            }
            used[piv] = true;
            let s = dmax.sqrt();
            let a_col = col(piv);
            // L[piv, ..k] is read by every row update; snapshot it once
            let lpiv: Vec<f64> = (0..k).map(|j| l[(piv, j)].to_f64()).collect();
            let mut newcol = vec![T::ZERO; n];
            {
                let lref = &l;
                let usedref = &used;
                let a_ref = &a_col;
                let update = |ci: usize, cseg: &mut [T], dseg: &mut [f64]| {
                    let base = ci * ROW_BLOCK;
                    for (off, (cv, dv)) in cseg.iter_mut().zip(dseg.iter_mut()).enumerate() {
                        let i = base + off;
                        if i == piv {
                            *cv = T::from_f64(s);
                            continue;
                        }
                        if usedref[i] {
                            *cv = T::ZERO;
                            continue;
                        }
                        let mut acc = a_ref[i].to_f64();
                        for (j, lp) in lpiv.iter().enumerate() {
                            acc -= lref[(i, j)].to_f64() * lp;
                        }
                        let v = acc / s;
                        *cv = T::from_f64(v);
                        *dv = (*dv - v * v).max(0.0);
                    }
                };
                // early columns do ~n*k flops — below the persistent
                // pool's dispatch break-even (re-tuned 8x down from the
                // spawn-era 1<<17), run inline: one whole-slice
                // "chunk 0" is bit-identical to the chunked sweep
                if n * (k + 1) < 1 << 14 {
                    update(0, &mut newcol, &mut d);
                } else {
                    crate::par::par_zip_mut_steal(
                        "precond.pivchol_col",
                        &mut newcol,
                        &mut d,
                        ROW_BLOCK,
                        &update,
                    );
                }
            }
            for (i, cv) in newcol.iter().enumerate() {
                l[(i, k)] = *cv;
            }
            d[piv] = 0.0;
            k_eff = k + 1;
        }
        // trim unused columns
        let mut ltrim = Matrix::<T>::zeros(n, k_eff.max(1));
        for i in 0..n {
            for j in 0..k_eff.max(1).min(rank) {
                ltrim[(i, j)] = l[(i, j)];
            }
        }
        Self::try_low_rank(ltrim, sigma2)
    }

    /// Apply M^{-1} to each row of `r`. Rows are independent systems,
    /// so they are distributed across the worker pool (each row's solve
    /// runs internally sequential — thread-count invariant).
    ///
    /// Honours the `precond_apply` failpoint (`nan` poisons the output
    /// so the CG indefinite-preconditioner detector and the mid-solve
    /// downgrade path can be exercised deterministically).
    pub fn apply_batch(&self, r: &Matrix<T>) -> Matrix<T> {
        let mut out = self.apply_batch_inner(r);
        if let Some(FaultAction::Nan) = failpoint::check("precond_apply") {
            if !out.data.is_empty() {
                out.data[0] = T::from_f64(f64::NAN);
            }
        }
        out
    }

    fn apply_batch_inner(&self, r: &Matrix<T>) -> Matrix<T> {
        match self {
            Preconditioner::Identity => r.clone(),
            Preconditioner::Jacobi { inv_diag } => {
                let mut out = r.clone();
                let cols = out.cols;
                crate::par::par_chunks_mut_cheap(
                    "precond.jacobi",
                    &mut out.data,
                    cols.max(1),
                    |_, row| {
                        for (x, d) in row.iter_mut().zip(inv_diag) {
                            *x *= *d;
                        }
                    },
                );
                out
            }
            Preconditioner::LowRankPlusNoise { l, sigma2, cap_chol } => {
                let mut out = Matrix::zeros(r.rows, r.cols);
                let inv_s2 = T::ONE / *sigma2;
                let cols = r.cols;
                let row_len = cols.max(1);
                crate::par::par_chunks_mut("precond.woodbury", &mut out.data, row_len, |b, orow| {
                    let rb = r.row(b);
                    let lt_r = l.matvec_t(rb); // r-dim
                    let sol = cap_chol.solve(&lt_r);
                    let l_sol = l.matvec(&sol);
                    for ((o, ri), ls) in orow.iter_mut().zip(rb).zip(&l_sol) {
                        *o = inv_s2 * (*ri - *ls);
                    }
                });
                out
            }
            Preconditioner::KronEig { lift, proj, inv_evals } => {
                let mut u = proj.apply_batch(r);
                let cols = u.cols;
                crate::par::par_chunks_mut_cheap(
                    "precond.kron_eig",
                    &mut u.data,
                    cols.max(1),
                    |_, row| {
                        for (x, iv) in row.iter_mut().zip(inv_evals) {
                            *x *= *iv;
                        }
                    },
                );
                lift.apply_batch(&u)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_woodbury_matches_dense_inverse() {
        prop_check("woodbury", 91, 15, |g| {
            let n = g.size(1, 20);
            let r = g.size(1, n.min(6));
            let l = Matrix::from_vec(n, r, g.vec_normal(n * r));
            let sigma2 = g.f64_in(0.1, 2.0);
            let pre = Preconditioner::low_rank(l.clone(), sigma2);
            // dense M
            let mut m = l.matmul(&l.transpose());
            m.add_diag(sigma2);
            let rhs = Matrix::from_vec(2, n, g.vec_normal(2 * n));
            let got = pre.apply_batch(&rhs);
            let ch = cholesky(&m).ok_or("M not PD")?;
            for b in 0..2 {
                let want = ch.solve(rhs.row(b));
                assert_close(got.row(b), &want, 1e-6)?;
            }
            Ok(())
        });
    }

    #[test]
    fn pivoted_from_columns_matches_direct_pivoted() {
        prop_check("lazy-pivchol", 97, 10, |g| {
            let n = g.size(2, 18);
            let a = g.spd(n);
            let am = Matrix::from_vec(n, n, a.clone());
            let diag: Vec<f64> = (0..n).map(|i| am[(i, i)]).collect();
            let am2 = am.clone();
            let pre = Preconditioner::<f64>::pivoted_from_columns(
                diag,
                move |j| am2.col(j),
                n,
                0.5,
            );
            // full-rank pivoted chol + noise must invert A + 0.5 I
            let mut m = am.clone();
            m.add_diag(0.5);
            let rhs = Matrix::from_vec(1, n, g.vec_normal(n));
            let got = pre.apply_batch(&rhs);
            let ch = cholesky(&m).ok_or("not PD")?;
            let want = ch.solve(rhs.row(0));
            assert_close(got.row(0), &want, 1e-5)
        });
    }

    #[test]
    fn construction_failures_are_typed() {
        // NaN sneaks past the clamp in the infallible path, so try_jacobi
        // must reject it up front
        let err = Preconditioner::<f64>::try_jacobi(&[1.0, f64::NAN, 2.0]).err();
        assert!(
            matches!(err, Some(PrecondError::NonFiniteDiag { index: 1, .. })),
            "{err:?}"
        );
        // sigma2 = 0 with a rank-deficient L -> singular capacitance
        let l = Matrix::<f64>::zeros(4, 2);
        let err = Preconditioner::try_low_rank(l, 0.0).err();
        assert!(
            matches!(err, Some(PrecondError::CapacitanceNotPd { rank: 2 })),
            "{err:?}"
        );
        // and the lazy builder surfaces a bad diagonal the same way
        let err = Preconditioner::<f64>::try_pivoted_from_columns(
            vec![1.0, f64::INFINITY],
            |_| vec![0.0; 2],
            2,
            0.1,
        )
        .err();
        assert!(matches!(err, Some(PrecondError::NonFiniteDiag { index: 1, .. })), "{err:?}");
        // a zero diagonal (zero-noise system, masked cell) is degenerate:
        // typed error, not a silently huge inverse scale
        let err = Preconditioner::<f64>::try_jacobi(&[1.0, 0.0, 2.0]).err();
        assert!(
            matches!(err, Some(PrecondError::NonPositiveDiag { index: 1, .. })),
            "{err:?}"
        );
        let err = Preconditioner::<f64>::try_jacobi(&[-0.5]).err();
        assert!(
            matches!(err, Some(PrecondError::NonPositiveDiag { index: 0, .. })),
            "{err:?}"
        );
        // kron-eig surfaces factor failures typed as well
        let mut bad = Matrix::zeros(2, 2);
        bad[(0, 0)] = f64::NAN;
        let ok = Matrix::from_fn(2, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        let err = Preconditioner::<f64>::try_kron_eig(&bad, &ok, 0.1).err();
        assert!(matches!(err, Some(PrecondError::KronEig(_))), "{err:?}");
    }

    #[test]
    fn prop_kron_eig_matches_dense_inverse() {
        prop_check("kron-eig-precond", 419, 10, |g| {
            let (p, q) = (g.size(1, 6), g.size(1, 6));
            let kss = Matrix::from_vec(p, p, g.spd(p));
            let ktt = Matrix::from_vec(q, q, g.spd(q));
            let sigma2 = g.f64_in(0.05, 1.0);
            let pre = Preconditioner::<f64>::try_kron_eig(&kss, &ktt, sigma2)
                .map_err(|e| e.to_string())?;
            let n = p * q;
            let mut dense = crate::kron::KronOp::new(kss, ktt).dense();
            dense.add_diag(sigma2);
            let rhs = Matrix::from_vec(2, n, g.vec_normal(2 * n));
            let got = pre.apply_batch(&rhs);
            let ch = cholesky(&dense).ok_or("dense system not PD")?;
            for b in 0..2 {
                let want = ch.solve(rhs.row(b));
                assert_close(got.row(b), &want, 1e-7)?;
            }
            Ok(())
        });
    }

    #[test]
    fn jacobi_scales() {
        let pre = Preconditioner::<f64>::jacobi(&[2.0, 4.0]);
        let r = Matrix::from_vec(1, 2, vec![2.0, 4.0]);
        let out = pre.apply_batch(&r);
        assert_close(out.row(0), &[1.0, 1.0], 1e-12).unwrap();
    }
}
