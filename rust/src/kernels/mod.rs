//! Kernel functions (rust side).
//!
//! These mirror the L2 JAX kernel families *exactly* — same functional
//! forms, same flat hyperparameter layout (`theta`), log-scale for
//! positive quantities — so the RustKron backend, the dense baselines,
//! and the PJRT artifacts all consume one hyperparameter vector:
//!
//! ```text
//! theta = [ log_ls_s (ARD, d_s) | log_outputscale | time-kernel params ]
//! ```
//!
//! Time-kernel params per family:
//!   rbf           -> [log_ls_t]
//!   rbf_periodic  -> [log_ls_t, log_ls_per, log_period]
//!   icm           -> [q*(q+1)/2 Cholesky entries, exp() on the diagonal]

pub mod grid;
pub mod matern;
pub mod rbf;
pub mod time;

pub use grid::ProductGridKernel;
pub use matern::{MaternArd, MaternNu};
pub use rbf::RbfArd;
pub use time::TimeKernel;
