//! ARD squared-exponential kernel with outputscale.

use crate::linalg::gemm::matmul_nt;
use crate::linalg::{Matrix, Scalar};
use crate::par;

/// k(x, y) = exp(log_os) * exp(-0.5 * sum_d (x_d - y_d)^2 / ls_d^2)
#[derive(Clone, Debug)]
pub struct RbfArd {
    /// Per-dimension log lengthscales (ARD).
    pub log_ls: Vec<f64>,
    /// Log outputscale.
    pub log_os: f64,
}

impl RbfArd {
    /// Unit-parameter kernel over `d` input dimensions.
    pub fn new(d: usize) -> Self {
        RbfArd { log_ls: vec![0.0; d], log_os: 0.0 }
    }

    /// Input dimension d.
    pub fn dim(&self) -> usize {
        self.log_ls.len()
    }

    /// Kernel value k(x, y).
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.dim());
        let mut d2 = 0.0;
        for ((xi, yi), lls) in x.iter().zip(y).zip(&self.log_ls) {
            let z = (xi - yi) / lls.exp();
            d2 += z * z;
        }
        self.log_os.exp() * (-0.5 * d2).exp()
    }

    /// Gram matrix via the GEMM trick: with inputs pre-scaled by 1/ls,
    /// ||x-y||^2 = x.x + y.y - 2 x.y^T, so the O(n m d) inner work is a
    /// single matmul_nt — the same schedule as the L1 Pallas RBF kernel.
    pub fn gram(&self, xs: &Matrix<f64>, ys: &Matrix<f64>) -> Matrix<f64> {
        self.gram_in::<f64>(xs, ys)
    }

    /// Precision-generic Gram builder (the `Precision::F32` compute
    /// path). Inputs are lengthscale-scaled in f64 and rounded to `T`
    /// exactly once; the O(n m d) GEMM and the distance/exp post-pass
    /// then run natively in `T`, so the f32 instantiation gets the full
    /// SIMD-width/bandwidth benefit. `gram_in::<f64>` is bit-identical
    /// to the original f64-only implementation.
    pub fn gram_in<T: Scalar>(&self, xs: &Matrix<f64>, ys: &Matrix<f64>) -> Matrix<T> {
        assert_eq!(xs.cols, self.dim());
        assert_eq!(ys.cols, self.dim());
        let scale: Vec<f64> = self.log_ls.iter().map(|l| (-l).exp()).collect();
        let scaled = |m: &Matrix<f64>| -> Matrix<T> {
            let mut s = Matrix::<T>::zeros(m.rows, m.cols);
            for i in 0..m.rows {
                for ((v, x), sc) in s.row_mut(i).iter_mut().zip(m.row(i)).zip(&scale) {
                    *v = T::from_f64(x * sc);
                }
            }
            s
        };
        let (xs_s, ys_s) = (scaled(xs), scaled(ys));
        let sqn = |m: &Matrix<T>| -> Vec<T> {
            (0..m.rows)
                .map(|i| {
                    let mut acc = T::ZERO;
                    for v in m.row(i) {
                        acc += *v * *v;
                    }
                    acc
                })
                .collect()
        };
        let (xn, yn) = (sqn(&xs_s), sqn(&ys_s));
        let mut k = matmul_nt(&xs_s, &ys_s);
        let os = T::from_f64(self.log_os.exp());
        let neg_half = T::from_f64(-0.5);
        let two = T::from_f64(2.0);
        // distance/exp post-pass, one Gram row per chunk: parallel over
        // the `par::` pool above the cheap-sweep threshold, sequential
        // below it — bit-identical either way (each cell's arithmetic
        // is independent and order-free across cells).
        let cols = k.cols;
        par::par_chunks_mut_cheap("rbf.gram_post", &mut k.data, cols.max(1), |i, row| {
            let xi = xn[i];
            for (v, yj) in row.iter_mut().zip(&yn) {
                let mut d2 = xi + *yj - two * *v;
                if d2 < T::ZERO {
                    d2 = T::ZERO;
                }
                *v = os * (neg_half * d2).exp();
            }
        });
        k
    }

    /// Symmetric Gram with optional diagonal jitter.
    pub fn gram_sym(&self, xs: &Matrix<f64>, jitter: f64) -> Matrix<f64> {
        let mut k = self.gram(xs, xs);
        if jitter > 0.0 {
            k.add_diag(jitter);
        }
        k
    }

    /// Flat hyperparameters `[log_ls.., log_os]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.log_ls.clone();
        p.push(self.log_os);
        p
    }

    /// Install flat hyperparameters `[log_ls.., log_os]`.
    pub fn set_params(&mut self, p: &[f64]) {
        let d = self.dim();
        assert_eq!(p.len(), d + 1);
        self.log_ls.copy_from_slice(&p[..d]);
        self.log_os = p[d];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, prop_check};

    #[test]
    fn prop_gram_matches_eval() {
        prop_check("rbf-gram-vs-eval", 41, 15, |g| {
            let d = g.size(1, 6);
            let (m, n) = (g.size(1, 20), g.size(1, 20));
            let mut k = RbfArd::new(d);
            k.log_ls = (0..d).map(|_| g.f64_in(-1.0, 1.0)).collect();
            k.log_os = g.f64_in(-1.0, 1.0);
            let xs = Matrix::from_vec(m, d, g.vec_normal(m * d));
            let ys = Matrix::from_vec(n, d, g.vec_normal(n * d));
            let gram = k.gram(&xs, &ys);
            let mut want = Vec::with_capacity(m * n);
            for i in 0..m {
                for j in 0..n {
                    want.push(k.eval(xs.row(i), ys.row(j)));
                }
            }
            assert_close(&gram.data, &want, 1e-9)
        });
    }

    #[test]
    fn prop_gram_f32_close_to_f64() {
        prop_check("rbf-gram-f32", 43, 10, |g| {
            let d = g.size(1, 4);
            let (m, n) = (g.size(1, 12), g.size(1, 12));
            let mut k = RbfArd::new(d);
            k.log_ls = (0..d).map(|_| g.f64_in(-0.5, 0.5)).collect();
            k.log_os = g.f64_in(-0.5, 0.5);
            let xs = Matrix::from_vec(m, d, g.vec_normal(m * d));
            let ys = Matrix::from_vec(n, d, g.vec_normal(n * d));
            let g64 = k.gram(&xs, &ys);
            let g32 = k.gram_in::<f32>(&xs, &ys);
            let wide: Vec<f64> = g32.data.iter().map(|&x| x as f64).collect();
            assert_close(&wide, &g64.data, 1e-5)
        });
    }

    #[test]
    fn diag_equals_outputscale() {
        let mut k = RbfArd::new(3);
        k.log_os = 0.7;
        let xs = Matrix::from_fn(5, 3, |i, j| (i * j) as f64 * 0.3);
        let gram = k.gram_sym(&xs, 0.0);
        for i in 0..5 {
            assert!((gram[(i, i)] - 0.7f64.exp()).abs() < 1e-9);
        }
    }

    #[test]
    fn params_roundtrip() {
        let mut k = RbfArd::new(2);
        k.set_params(&[0.1, -0.2, 0.5]);
        assert_eq!(k.params(), vec![0.1, -0.2, 0.5]);
    }

    #[test]
    fn longer_lengthscale_higher_correlation() {
        let mut k = RbfArd::new(1);
        k.log_ls[0] = 0.0;
        let near = k.eval(&[0.0], &[1.0]);
        k.log_ls[0] = 2.0;
        let far = k.eval(&[0.0], &[1.0]);
        assert!(far > near);
    }
}
