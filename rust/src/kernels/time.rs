//! Time/task kernel families for the T axis of the product kernel.
//!
//! Mirrors python/compile/model.py::time_gram exactly (same math, same
//! parameter packing) — integration tests assert rust and PJRT agree.

use crate::linalg::Matrix;

/// K_TT family. `t` inputs are the q grid coordinates (as f64 scalars
/// for rbf/rbf_periodic; ignored for icm, which keys on task index).
#[derive(Clone, Debug)]
pub enum TimeKernel {
    /// Squared exponential on t: params [log_ls_t].
    Rbf {
        /// Log lengthscale on t.
        log_ls: f64,
    },
    /// SE * periodic (seasonal trends): [log_ls_t, log_ls_per, log_period].
    RbfPeriodic {
        /// Log lengthscale of the SE envelope.
        log_ls: f64,
        /// Log lengthscale inside the periodic term.
        log_ls_per: f64,
        /// Log period.
        log_period: f64,
    },
    /// Full-rank ICM task kernel B = L L^T over q tasks:
    /// [q*(q+1)/2 packed row-major lower-triangular entries of L,
    /// exp() applied to diagonal entries for positivity].
    Icm {
        /// Number of tasks.
        q: usize,
        /// Packed lower-triangular entries of L (row-major).
        tril: Vec<f64>,
    },
}

impl TimeKernel {
    /// Construct a unit-parameter kernel of the named family
    /// (`"rbf"` | `"rbf_periodic"` | `"icm"`); panics on other names.
    pub fn new(family: &str, q: usize) -> Self {
        match family {
            "rbf" => TimeKernel::Rbf { log_ls: 0.0 },
            "rbf_periodic" => {
                TimeKernel::RbfPeriodic { log_ls: 0.0, log_ls_per: 0.0, log_period: 0.0 }
            }
            "icm" => TimeKernel::Icm { q, tril: vec![0.0; q * (q + 1) / 2] },
            other => panic!("unknown time kernel family {other:?}"),
        }
    }

    /// Family name as accepted by [`TimeKernel::new`].
    pub fn family(&self) -> &'static str {
        match self {
            TimeKernel::Rbf { .. } => "rbf",
            TimeKernel::RbfPeriodic { .. } => "rbf_periodic",
            TimeKernel::Icm { .. } => "icm",
        }
    }

    /// Number of hyperparameters in this family's flat packing.
    pub fn n_params(&self) -> usize {
        match self {
            TimeKernel::Rbf { .. } => 1,
            TimeKernel::RbfPeriodic { .. } => 3,
            TimeKernel::Icm { q, .. } => q * (q + 1) / 2,
        }
    }

    /// Flat hyperparameter vector (family-specific packing).
    pub fn params(&self) -> Vec<f64> {
        match self {
            TimeKernel::Rbf { log_ls } => vec![*log_ls],
            TimeKernel::RbfPeriodic { log_ls, log_ls_per, log_period } => {
                vec![*log_ls, *log_ls_per, *log_period]
            }
            TimeKernel::Icm { tril, .. } => tril.clone(),
        }
    }

    /// Install a flat hyperparameter vector (asserts the length).
    pub fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        match self {
            TimeKernel::Rbf { log_ls } => *log_ls = p[0],
            TimeKernel::RbfPeriodic { log_ls, log_ls_per, log_period } => {
                *log_ls = p[0];
                *log_ls_per = p[1];
                *log_period = p[2];
            }
            TimeKernel::Icm { tril, .. } => tril.copy_from_slice(p),
        }
    }

    /// Gram matrix over grid coordinates `t` (length q).
    pub fn gram(&self, t: &[f64]) -> Matrix<f64> {
        let q = t.len();
        match self {
            TimeKernel::Rbf { log_ls } => {
                let ls = log_ls.exp();
                Matrix::from_fn(q, q, |i, j| {
                    let d = (t[i] - t[j]) / ls;
                    (-0.5 * d * d).exp()
                })
            }
            TimeKernel::RbfPeriodic { log_ls, log_ls_per, log_period } => {
                let (ls, lsp, period) = (log_ls.exp(), log_ls_per.exp(), log_period.exp());
                Matrix::from_fn(q, q, |i, j| {
                    let d = t[i] - t[j];
                    let se = (-0.5 * d * d / (ls * ls)).exp();
                    let s = (std::f64::consts::PI * d / period).sin();
                    let per = (-2.0 * s * s / (lsp * lsp)).exp();
                    se * per
                })
            }
            TimeKernel::Icm { q: qq, .. } => {
                assert_eq!(q, *qq, "ICM gram requires q grid points");
                let l = self.icm_l();
                let mut k = l.matmul(&l.transpose());
                k.add_diag(1e-6);
                k
            }
        }
    }

    /// Whether the family is stationary in t — K_TT[i][j] depends only
    /// on t[i] - t[j]. Stationary + uniform grid ⇒ K_TT is Toeplitz,
    /// which is what the `auto` time-op mode checks before engaging the
    /// FFT fast path. ICM keys on task index, not a metric, so it is
    /// not stationary.
    pub fn is_stationary(&self) -> bool {
        match self {
            TimeKernel::Rbf { .. } | TimeKernel::RbfPeriodic { .. } => true,
            TimeKernel::Icm { .. } => false,
        }
    }

    /// The lower-triangular ICM factor L (exp on diagonal).
    pub fn icm_l(&self) -> Matrix<f64> {
        match self {
            TimeKernel::Icm { q, tril } => {
                let mut l = Matrix::zeros(*q, *q);
                let mut idx = 0;
                for i in 0..*q {
                    for j in 0..=i {
                        l[(i, j)] = if i == j { tril[idx].exp() } else { tril[idx] };
                        idx += 1;
                    }
                }
                l
            }
            _ => panic!("icm_l on non-ICM kernel"),
        }
    }
}

/// Result of [`detect_uniform_spacing`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GridSpacing {
    /// Consecutive spacings all match the mean spacing `dt` to the
    /// requested relative tolerance (dt = 0.0 for grids of length <= 1).
    Uniform {
        /// The common grid spacing.
        dt: f64,
    },
    /// At least one spacing deviates beyond tolerance.
    Irregular,
}

/// Classify a time grid as uniformly spaced or not. Every consecutive
/// difference must match the mean spacing `(t[q-1] - t[0]) / (q-1)`
/// within `rel_tol` relative to that mean (absolute when the mean is
/// ~0). Grids of length <= 1 are trivially uniform. Used by the `auto`
/// time-op mode to decide whether K_TT is Toeplitz.
pub fn detect_uniform_spacing(t: &[f64], rel_tol: f64) -> GridSpacing {
    let q = t.len();
    if q <= 1 {
        return GridSpacing::Uniform { dt: 0.0 };
    }
    let dt = (t[q - 1] - t[0]) / (q - 1) as f64;
    let tol = rel_tol * dt.abs().max(f64::EPSILON);
    for w in t.windows(2) {
        if ((w[1] - w[0]) - dt).abs() > tol {
            return GridSpacing::Irregular;
        }
    }
    GridSpacing::Uniform { dt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;

    fn grid(q: usize) -> Vec<f64> {
        (0..q).map(|i| i as f64 / (q.max(2) - 1) as f64).collect()
    }

    #[test]
    fn rbf_unit_diag_and_symmetry() {
        let k = TimeKernel::new("rbf", 8);
        let g = k.gram(&grid(8));
        for i in 0..8 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-12);
            for j in 0..8 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn periodic_repeats_at_period() {
        let mut k = TimeKernel::new("rbf_periodic", 0);
        // long SE lengthscale so the periodic part dominates
        k.set_params(&[3.0f64.ln(), 0.0, 0.25f64.ln()]);
        let t = [0.0, 0.25, 0.5, 0.125];
        let g = k.gram(&t);
        // lag exactly one period -> periodic factor is 1
        assert!((g[(0, 1)] - g[(0, 2)]).abs() < 0.05, "{} {}", g[(0, 1)], g[(0, 2)]);
        assert!(g[(0, 3)] < g[(0, 1)]); // half-period lag is least similar
    }

    #[test]
    fn icm_gram_is_psd_full_rank() {
        let mut k = TimeKernel::new("icm", 5);
        let p: Vec<f64> = (0..k.n_params()).map(|i| (i as f64 * 0.37).sin() * 0.5).collect();
        k.set_params(&p);
        let g = k.gram(&grid(5));
        assert!(cholesky(&g).is_some(), "ICM gram not PD");
    }

    #[test]
    fn stationarity_by_family() {
        assert!(TimeKernel::new("rbf", 4).is_stationary());
        assert!(TimeKernel::new("rbf_periodic", 4).is_stationary());
        assert!(!TimeKernel::new("icm", 4).is_stationary());
    }

    #[test]
    fn uniform_spacing_detects_regular_grids() {
        let t: Vec<f64> = (0..50).map(|i| 0.3 + i as f64 * 0.02).collect();
        match detect_uniform_spacing(&t, 1e-8) {
            GridSpacing::Uniform { dt } => assert!((dt - 0.02).abs() < 1e-12),
            GridSpacing::Irregular => panic!("regular grid flagged irregular"),
        }
    }

    #[test]
    fn uniform_spacing_rejects_jitter_beyond_tolerance() {
        let mut t: Vec<f64> = (0..20).map(|i| i as f64 * 0.1).collect();
        t[7] += 0.01; // 10% jitter on one step
        assert_eq!(detect_uniform_spacing(&t, 1e-4), GridSpacing::Irregular);
        // ...but a loose tolerance accepts the same grid
        assert!(matches!(detect_uniform_spacing(&t, 0.5), GridSpacing::Uniform { .. }));
        // tiny float noise passes at a sane tolerance
        let t2: Vec<f64> = (0..20).map(|i| i as f64 * 0.1 + (i % 3) as f64 * 1e-12).collect();
        assert!(matches!(detect_uniform_spacing(&t2, 1e-6), GridSpacing::Uniform { .. }));
    }

    #[test]
    fn uniform_spacing_rejects_irregular_grids() {
        assert_eq!(
            detect_uniform_spacing(&[0.0, 1.0, 3.0, 6.0], 1e-6),
            GridSpacing::Irregular
        );
    }

    #[test]
    fn uniform_spacing_degenerate_lengths_are_uniform() {
        assert_eq!(detect_uniform_spacing(&[], 1e-6), GridSpacing::Uniform { dt: 0.0 });
        assert_eq!(detect_uniform_spacing(&[4.2], 1e-6), GridSpacing::Uniform { dt: 0.0 });
    }

    #[test]
    fn param_roundtrip_all_families() {
        for fam in ["rbf", "rbf_periodic", "icm"] {
            let mut k = TimeKernel::new(fam, 4);
            let p: Vec<f64> = (0..k.n_params()).map(|i| i as f64 * 0.1 - 0.2).collect();
            k.set_params(&p);
            assert_eq!(k.params(), p, "{fam}");
            assert_eq!(k.family(), fam);
        }
    }
}
