//! Matérn kernels (nu = 1/2, 3/2, 5/2) — the "specialized kernels"
//! future-work item (paper Sec. 5). Drop-in spatial alternatives to the
//! squared exponential for rougher fields (precipitation, terrain).

use crate::linalg::Matrix;

/// Smoothness order of the Matérn family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MaternNu {
    /// nu = 1/2 (exponential kernel, continuous but not differentiable).
    Half,
    /// nu = 3/2 (once differentiable).
    ThreeHalves,
    /// nu = 5/2 (twice differentiable).
    FiveHalves,
}

/// Isotropic Matérn kernel with ARD lengthscales and outputscale.
#[derive(Clone, Debug)]
pub struct MaternArd {
    /// Smoothness order.
    pub nu: MaternNu,
    /// Per-dimension log lengthscales (ARD).
    pub log_ls: Vec<f64>,
    /// Log outputscale.
    pub log_os: f64,
}

impl MaternArd {
    /// Unit-parameter kernel over `d` input dimensions.
    pub fn new(nu: MaternNu, d: usize) -> Self {
        MaternArd { nu, log_ls: vec![0.0; d], log_os: 0.0 }
    }

    /// Input dimension d.
    pub fn dim(&self) -> usize {
        self.log_ls.len()
    }

    /// Scaled distance r = sqrt(sum_d ((x_d - y_d)/ls_d)^2).
    fn scaled_r(&self, x: &[f64], y: &[f64]) -> f64 {
        let mut r2 = 0.0;
        for ((xi, yi), lls) in x.iter().zip(y).zip(&self.log_ls) {
            let z = (xi - yi) / lls.exp();
            r2 += z * z;
        }
        r2.sqrt()
    }

    /// Kernel value k(x, y).
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let r = self.scaled_r(x, y);
        let core = match self.nu {
            MaternNu::Half => (-r).exp(),
            MaternNu::ThreeHalves => {
                let a = 3f64.sqrt() * r;
                (1.0 + a) * (-a).exp()
            }
            MaternNu::FiveHalves => {
                let a = 5f64.sqrt() * r;
                (1.0 + a + a * a / 3.0) * (-a).exp()
            }
        };
        self.log_os.exp() * core
    }

    /// Cross-Gram matrix over rows of `xs` and `ys`.
    pub fn gram(&self, xs: &Matrix<f64>, ys: &Matrix<f64>) -> Matrix<f64> {
        Matrix::from_fn(xs.rows, ys.rows, |i, j| self.eval(xs.row(i), ys.row(j)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::util::rng::Rng;

    fn points(n: usize, d: usize, seed: u64) -> Matrix<f64> {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(n, d, rng.normals(n * d))
    }

    #[test]
    fn all_nus_are_psd_kernels() {
        for nu in [MaternNu::Half, MaternNu::ThreeHalves, MaternNu::FiveHalves] {
            let k = MaternArd::new(nu, 3);
            let xs = points(25, 3, 1);
            let mut g = k.gram(&xs, &xs);
            g.add_diag(1e-8);
            assert!(cholesky(&g).is_some(), "{nu:?} gram not PSD");
        }
    }

    #[test]
    fn smoothness_ordering_near_origin() {
        // higher nu decays slower near r=0 (smoother process)
        let x = [0.0];
        let y = [0.4];
        let vals: Vec<f64> = [MaternNu::Half, MaternNu::ThreeHalves, MaternNu::FiveHalves]
            .iter()
            .map(|&nu| MaternArd::new(nu, 1).eval(&x, &y))
            .collect();
        assert!(vals[0] < vals[1] && vals[1] < vals[2], "{vals:?}");
    }

    #[test]
    fn matern_52_approaches_se_for_small_r() {
        let m = MaternArd::new(MaternNu::FiveHalves, 1);
        let se = crate::kernels::RbfArd::new(1);
        for r in [0.01, 0.05] {
            let km = m.eval(&[0.0], &[r]);
            let ks = se.eval(&[0.0], &[r]);
            assert!((km - ks).abs() < 5e-3, "r={r}: {km} vs {ks}");
        }
    }

    #[test]
    fn diag_is_outputscale() {
        let mut k = MaternArd::new(MaternNu::ThreeHalves, 2);
        k.log_os = 0.4;
        let xs = points(5, 2, 2);
        let g = k.gram(&xs, &xs);
        for i in 0..5 {
            assert!((g[(i, i)] - 0.4f64.exp()).abs() < 1e-12);
        }
    }
}
