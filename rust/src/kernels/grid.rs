//! The product kernel on the S x T grid — the model class of the paper.
//!
//! `k_X((s,t), (s',t')) = k_S(s, s') * k_T(t, t')`, with a shared flat
//! hyperparameter vector matching the AOT artifacts' `theta` ABI.

use crate::linalg::{Matrix, Scalar};

use super::rbf::RbfArd;
use super::time::TimeKernel;

/// Product kernel k_S (ARD-SE over s) x k_T (time family over t).
#[derive(Clone, Debug)]
pub struct ProductGridKernel {
    /// Spatial factor k_S (ARD squared exponential).
    pub spatial: RbfArd,
    /// Time/task factor k_T.
    pub time: TimeKernel,
}

impl ProductGridKernel {
    /// Product kernel over `ds` spatial dimensions and a q-point time
    /// grid of the named family.
    pub fn new(ds: usize, time_family: &str, q: usize) -> Self {
        ProductGridKernel { spatial: RbfArd::new(ds), time: TimeKernel::new(time_family, q) }
    }

    /// Total hyperparameter count (matches python configs.n_theta).
    pub fn n_theta(&self) -> usize {
        self.spatial.dim() + 1 + self.time.n_params()
    }

    /// Flat theta = [log_ls_s.., log_os, time params..].
    pub fn theta(&self) -> Vec<f64> {
        let mut p = self.spatial.params();
        p.extend(self.time.params());
        p
    }

    /// Install the flat theta vector (asserts the length).
    pub fn set_theta(&mut self, theta: &[f64]) {
        assert_eq!(theta.len(), self.n_theta(), "theta length");
        let ns = self.spatial.dim() + 1;
        self.spatial.set_params(&theta[..ns]);
        self.time.set_params(&theta[ns..]);
    }

    /// theta as f32 for the PJRT boundary.
    pub fn theta_f32(&self) -> Vec<f32> {
        crate::util::convert::f32_vec(&self.theta())
    }

    /// K_SS over spatial points (rows of `s`).
    pub fn gram_s(&self, s: &Matrix<f64>) -> Matrix<f64> {
        self.spatial.gram(s, s)
    }

    /// K_SS in the requested compute precision: the O(p^2 d) spatial
    /// Gram runs natively in `T` (see [`RbfArd::gram_in`]).
    pub fn gram_s_in<T: Scalar>(&self, s: &Matrix<f64>) -> Matrix<T> {
        self.spatial.gram_in(s, s)
    }

    /// K_TT over time coordinates.
    pub fn gram_t(&self, t: &[f64]) -> Matrix<f64> {
        self.time.gram(t)
    }

    /// K_TT in the requested compute precision. The time Gram is only
    /// O(q^2) with q small (genericity inside `TimeKernel` would be
    /// disproportionate), so it is computed in f64 and rounded once at
    /// the precision boundary.
    pub fn gram_t_in<T: Scalar>(&self, t: &[f64]) -> Matrix<T> {
        self.time.gram(t).cast()
    }

    /// Full product-kernel evaluation between two grid points.
    pub fn eval(&self, s1: &[f64], t1: f64, s2: &[f64], t2: f64, t_grid: &[f64]) -> f64 {
        // for ICM, t is a task index into the grid
        let kt = match &self.time {
            TimeKernel::Icm { .. } => {
                let g = self.time.gram(t_grid);
                let (i, j) = (t1 as usize, t2 as usize);
                g[(i, j)]
            }
            _ => {
                let g = self.time.gram(&[t1, t2]);
                g[(0, 1)]
            }
        };
        self.spatial.eval(s1, s2) * kt
    }

    /// Dense n x n kernel matrix over an arbitrary list of (row, col)
    /// grid observations — what the *dense baseline* materializes. Each
    /// observation is (spatial index, time index) into the grids. Rows
    /// are filled in parallel over the `crate::par` pool above the
    /// cheap-sweep threshold (each cell is an independent product, so
    /// the result is bit-identical for any thread count).
    pub fn dense_gram(
        &self,
        s: &Matrix<f64>,
        t: &[f64],
        obs: &[(usize, usize)],
    ) -> Matrix<f64> {
        let kss = self.gram_s(s);
        let ktt = self.gram_t(t);
        let n = obs.len();
        let mut k = Matrix::zeros(n, n);
        crate::par::par_chunks_mut_cheap("grid.dense_gram", &mut k.data, n.max(1), |a, row| {
            let (ia, ja) = obs[a];
            for (v, &(ib, jb)) in row.iter_mut().zip(obs) {
                *v = kss[(ia, ib)] * ktt[(ja, jb)];
            }
        });
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn theta_roundtrip_matches_layout() {
        let mut k = ProductGridKernel::new(3, "rbf_periodic", 10);
        assert_eq!(k.n_theta(), 3 + 1 + 3);
        let theta: Vec<f64> = (0..k.n_theta()).map(|i| i as f64 * 0.01).collect();
        k.set_theta(&theta);
        assert_eq!(k.theta(), theta);
    }

    #[test]
    fn dense_gram_is_product_of_factors() {
        let mut rng = Rng::new(0);
        let k = ProductGridKernel::new(2, "rbf", 4);
        let s = Matrix::from_vec(3, 2, rng.normals(6));
        let t: Vec<f64> = vec![0.0, 0.3, 0.6, 1.0];
        let obs: Vec<(usize, usize)> = vec![(0, 0), (0, 3), (1, 1), (2, 2), (2, 0)];
        let dense = k.dense_gram(&s, &t, &obs);
        let (kss, ktt) = (k.gram_s(&s), k.gram_t(&t));
        for (a, &(ia, ja)) in obs.iter().enumerate() {
            for (b, &(ib, jb)) in obs.iter().enumerate() {
                let want = kss[(ia, ib)] * ktt[(ja, jb)];
                assert!((dense[(a, b)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn icm_task_count_matches() {
        let k = ProductGridKernel::new(21, "icm", 7);
        assert_eq!(k.n_theta(), 21 + 1 + 28);
    }
}
