//! Mini property-testing harness (no proptest offline).
//!
//! `prop_check` runs a property over N randomized cases drawn from a
//! seeded RNG; on failure it reports the failing case index and seed so
//! the case can be replayed deterministically. `Gen` wraps the RNG with
//! generators for the shapes/values the numeric property tests need.

use crate::linalg::Scalar;

use super::rng::Rng;

/// Value generators for property tests.
pub struct Gen {
    /// The underlying seeded generator (exposed for custom draws).
    pub rng: Rng,
}

impl Gen {
    /// Uniform size in `[lo, hi]` inclusive.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// n standard-normal f64 draws.
    pub fn vec_normal(&mut self, n: usize) -> Vec<f64> {
        self.rng.normals(n)
    }

    /// n standard-normal f32 draws.
    pub fn vec_normal_f32(&mut self, n: usize) -> Vec<f32> {
        self.rng.normals_f32(n)
    }

    /// Random SPD matrix (row-major n x n): A A^T + n I.
    pub fn spd(&mut self, n: usize) -> Vec<f64> {
        let a = self.rng.normals(n * n);
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * a[j * n + k];
                }
                out[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        out
    }

    /// Random {0,1} mask of length n with roughly `missing` fraction of
    /// zeros, guaranteed at least one observed entry.
    pub fn mask(&mut self, n: usize, missing: f64) -> Vec<f64> {
        let mut m: Vec<f64> = (0..n)
            .map(|_| if self.rng.uniform() < missing { 0.0 } else { 1.0 })
            .collect();
        if m.iter().all(|&x| x == 0.0) {
            let i = self.rng.below(n);
            m[i] = 1.0;
        }
        m
    }
}

/// Run `prop` over `cases` randomized inputs. Panics with replay info on
/// the first failure. `prop` returns Err(description) to fail.
pub fn prop_check<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(case_seed) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed {case_seed}): {msg}"
            );
        }
    }
}

/// Pick a tolerance by compute precision: `tol_f64` when `T` is f64,
/// `tol_f32` when `T` is f32. The precision-aware numeric tests
/// (rust/tests/numerics.rs) state both bounds at the call site so the
/// accuracy contract of each precision is explicit.
pub fn prec_tol<T: Scalar>(tol_f64: f64, tol_f32: f64) -> f64 {
    if T::NAME == "f32" {
        tol_f32
    } else {
        tol_f64
    }
}

/// Precision-aware [`assert_close`]: compares a `T`-valued result
/// against an f64 reference with a per-precision tolerance
/// (absolute + relative, like `assert_close`).
pub fn assert_close_prec<T: Scalar>(
    got: &[T],
    want: &[f64],
    tol_f64: f64,
    tol_f32: f64,
) -> Result<(), String> {
    let tol = prec_tol::<T>(tol_f64, tol_f32);
    if got.len() != want.len() {
        return Err(format!("length mismatch {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let g = g.to_f64();
        let scale = 1.0f64.max(w.abs());
        if (g - w).abs() > tol * scale {
            return Err(format!(
                "index {i}: got {g}, want {w} ({} tol {tol})",
                T::NAME
            ));
        }
    }
    Ok(())
}

/// Assert two slices are elementwise close (absolute + relative).
pub fn assert_close(got: &[f64], want: &[f64], tol: f64) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("length mismatch {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f64.max(w.abs());
        if (g - w).abs() > tol * scale {
            return Err(format!("index {i}: got {g}, want {w} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("add-commutes", 1, 50, |g| {
            let (a, b) = (g.f64_in(-5.0, 5.0), g.f64_in(-5.0, 5.0));
            assert_close(&[a + b], &[b + a], 1e-15)
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_failing_case() {
        prop_check("always-fails", 2, 3, |_| Err("nope".into()));
    }

    #[test]
    fn spd_is_symmetric_positive() {
        prop_check("spd", 3, 10, |g| {
            let n = g.size(1, 8);
            let a = g.spd(n);
            for i in 0..n {
                for j in 0..n {
                    if (a[i * n + j] - a[j * n + i]).abs() > 1e-9 {
                        return Err("not symmetric".into());
                    }
                }
                if a[i * n + i] <= 0.0 {
                    return Err("diag not positive".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prec_tol_selects_by_scalar() {
        assert_eq!(prec_tol::<f64>(1e-9, 1e-4), 1e-9);
        assert_eq!(prec_tol::<f32>(1e-9, 1e-4), 1e-4);
    }

    #[test]
    fn assert_close_prec_uses_precision_tolerance() {
        // 1e-5 off: fails the f64 bound, passes the f32 bound
        let want = [1.0f64];
        assert!(assert_close_prec::<f64>(&[1.0 + 1e-5], &want, 1e-9, 1e-3).is_err());
        assert!(assert_close_prec::<f32>(&[1.0 + 1e-5], &want, 1e-9, 1e-3).is_ok());
    }

    #[test]
    fn mask_never_empty() {
        prop_check("mask", 4, 20, |g| {
            let n = g.size(1, 50);
            let m = g.mask(n, 0.99);
            if m.iter().sum::<f64>() < 1.0 {
                return Err("all missing".into());
            }
            Ok(())
        });
    }
}
