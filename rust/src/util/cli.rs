//! Minimal CLI argument parser (no clap offline).
//!
//! Supports `lkgp <subcommand> [--flag] [--key value] [positional...]`.
//! Typed getters with defaults; unknown-flag detection via `finish()`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` flags, positionals.
///
/// Flags are repeatable: every occurrence of `--key value` is kept in
/// order. The scalar getters return the **last** occurrence (so a later
/// flag overrides an earlier one, the conventional CLI behavior) and
/// [`Args::str_all`] returns all of them (`lkgp serve --checkpoint a
/// --checkpoint b` loads both models).
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare argument, if any (e.g. `train`).
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value | --key value | --flag; repeats accumulate
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap_or_default(); // peek guarantees Some
                    out.flags.entry(key.to_string()).or_default().push(v);
                } else {
                    out.flags.entry(key.to_string()).or_default().push("true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// Raw flag value, if provided (last occurrence wins on repeats).
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).and_then(|vs| vs.last().cloned())
    }

    /// Every occurrence of a repeatable flag, in command-line order
    /// (empty when the flag was never given). `lkgp serve` uses this
    /// for its repeatable `--checkpoint`.
    pub fn str_all(&self, key: &str) -> Vec<String> {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_default()
    }

    /// String flag with a default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    /// Unsigned-integer flag with a default (unparseable -> default).
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// u64 flag with a default (unparseable -> default).
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Float flag with a default (unparseable -> default).
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean flag: present (and not `false`/`0`) means true.
    pub fn bool(&self, key: &str) -> bool {
        self.str_opt(key).map(|v| v != "false" && v != "0").unwrap_or(false)
    }

    /// Comma-separated list of floats, e.g. `--ratios 0.1,0.2,0.5`.
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.str_opt(key) {
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated list of unsigned integers, e.g. `--cells 0,5,12`
    /// (`lkgp predict`). Strict: `Ok(None)` when the flag is absent,
    /// and `Err` naming the offending token when any entry fails to
    /// parse — a typo must not silently change the query. Empty tokens
    /// (trailing commas) are ignored.
    pub fn usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, String> {
        let Some(raw) = self.str_opt(key) else {
            return Ok(None);
        };
        // a bare `--key` (no value) parses as the boolean sentinel
        if raw == "true" {
            return Err(format!("--{key} requires a comma-separated list of unsigned integers"));
        }
        let mut out = Vec::new();
        for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match tok.parse() {
                Ok(v) => out.push(v),
                Err(_) => {
                    return Err(format!("--{key}: {tok:?} is not an unsigned integer"))
                }
            }
        }
        Ok(Some(out))
    }

    /// Bare (non-flag) arguments after the subcommand.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Return Err listing any flags that were provided but never read.
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !seen.contains(k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown flags: {}",
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        // note: bare flags consume a following bare word as their value,
        // so positionals go before flags or bare flags go last.
        let a = parse("experiment pos1 --name fig3 --seeds 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.str("name", ""), "fig3");
        assert_eq!(a.usize("seeds", 0), 5);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn eq_style_and_lists() {
        let a = parse("run --ratios=0.1,0.5,0.9 --lr=0.1");
        assert_eq!(a.f64_list("ratios", &[]), vec![0.1, 0.5, 0.9]);
        assert_eq!(a.f64("lr", 0.0), 0.1);
    }

    #[test]
    fn usize_lists_are_strict() {
        let a = parse("predict --cells=3,1,4");
        assert_eq!(a.usize_list("cells"), Ok(Some(vec![3, 1, 4])));
        assert_eq!(a.usize_list("rows"), Ok(None));
        // trailing comma is tolerated, a typo is not
        let b = parse("predict --cells 0,5,");
        assert_eq!(b.usize_list("cells"), Ok(Some(vec![0, 5])));
        let c = parse("predict --cells 0,x2");
        assert!(c.usize_list("cells").unwrap_err().contains("\"x2\""));
        // a bare flag (value forgotten) errors instead of leaking the
        // boolean sentinel into the parse
        let d = parse("predict --cells --json out.json");
        assert!(d.usize_list("cells").unwrap_err().contains("requires"));
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("run --typo 3");
        let _ = a.str("name", "");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.usize("iters", 7), 7);
        assert_eq!(a.f64_list("r", &[0.5]), vec![0.5]);
    }

    #[test]
    fn negative_number_values() {
        let a = parse("run --offset -3.5");
        assert_eq!(a.f64("offset", 0.0), -3.5);
    }

    #[test]
    fn repeated_flags_accumulate_and_last_wins() {
        let a = parse("serve --checkpoint a.ckpt --checkpoint=b.ckpt --window 2 --window 5");
        assert_eq!(a.str_all("checkpoint"), vec!["a.ckpt".to_string(), "b.ckpt".to_string()]);
        // scalar getters see the last occurrence
        assert_eq!(a.u64("window", 0), 5);
        assert_eq!(a.str_all("missing"), Vec::<String>::new());
        assert!(a.finish().is_ok());
    }
}
