//! Minimal JSON codec (no serde in the offline crate set).
//!
//! Covers the full JSON grammar needed by `artifacts/manifest.json`,
//! experiment configs, and results files: objects, arrays, strings with
//! escapes, numbers, bools, null. Parsing is recursive-descent over
//! bytes; serialization pretty-prints with stable key order (insertion
//! order preserved via Vec-backed objects).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; insertion order is preserved for serialization.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object member lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for key in path {
            match cur.get(key) {
                Some(v) => cur = v,
                None => return &Json::Null,
            }
        }
        cur
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Key/value pairs, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    /// Object keys as a map for order-insensitive comparisons.
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(kv) => kv.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    /// Build an object from `(&str, Json)` pairs (insertion order kept).
    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers from an f64 slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array of numbers from a usize slice (e.g. the grid-cell index
    /// lists `lkgp predict --json` emits).
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

/// Parse failure with the byte offset where it occurred.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let Some(ch) = s.chars().next() else {
                        return Err(self.err("invalid utf8"));
                    };
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s, 0);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String, indent: usize) {
    let pad = |out: &mut String, n: usize| out.push_str(&"  ".repeat(n));
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push(' ');
                write_json(item, out, indent);
            }
            out.push_str(" ]");
        }
        Json::Obj(kv) => {
            if kv.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, val)) in kv.iter().enumerate() {
                pad(out, indent + 1);
                escape(k, out);
                out.push_str(": ");
                write_json(val, out, indent + 1);
                if i + 1 < kv.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(out, indent);
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"version": 1, "dtype": "f32",
            "configs": {"tiny": {"p": 16, "q": 8,
                "artifacts": {"kron_mvm": {"file": "a.hlo.txt",
                    "inputs": [{"name": "v", "shape": [4, 128]}]}}}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.at(&["configs", "tiny", "p"]).as_usize(), Some(16));
        let shape = v.at(&["configs", "tiny", "artifacts", "kron_mvm", "inputs"]);
        let first = &shape.as_arr().unwrap()[0];
        assert_eq!(first.get("name").unwrap().as_str(), Some("v"));
        // reparse of serialization
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v.to_map(), v2.to_map());
    }

    #[test]
    fn numbers() {
        for (s, want) in [("0", 0.0), ("-3.5", -3.5), ("1e3", 1000.0), ("2.5e-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want));
        }
    }

    #[test]
    fn strings_with_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "tru", "\"abc", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☂\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☂"));
    }
}
