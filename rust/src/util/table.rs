//! Markdown / CSV table rendering for experiment reports.
//!
//! Every experiment runner produces `Table`s that are printed to stdout
//! and written under results/, mirroring the paper's table layout
//! (metric blocks x model rows x dataset columns).

use std::fmt::Write as _;

/// A simple rectangular table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Caption printed above the table.
    pub title: String,
    /// Column headers.
    pub header: Vec<String>,
    /// Data rows (each matching the header arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (asserts the arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let body: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "| {} |", sep.join(" | "));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (minimal quoting).
    pub fn csv(&self) -> String {
        let quote = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ =
                writeln!(out, "{}", row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write markdown + csv under dir as `<stem>.md` / `<stem>.csv`.
    pub fn save(&self, dir: &std::path::Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("demo", &["model", "rmse"]);
        t.row(vec!["LKGP".into(), "0.08".into()]);
        t.row(vec!["SVGP".into(), "0.21".into()]);
        let md = t.markdown();
        assert!(md.contains("| model | rmse |"));
        assert!(md.contains("| LKGP  | 0.08 |"));
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "pl\"ain".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"pl\"\"ain\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
