//! Memory accounting.
//!
//! Two complementary views, mirroring the paper's Figure 2/3 memory axes:
//! * `ByteCounter` — analytic bytes for the kernel-matrix representations
//!   (dense n^2 vs latent-Kronecker p^2 + q^2), the quantity Prop. 3.1
//!   reasons about;
//! * `peak_rss_bytes` — process peak RSS from /proc for empirical checks.

/// Analytic byte accounting for matrix storage.
#[derive(Default, Debug, Clone, Copy)]
pub struct ByteCounter {
    /// Accumulated bytes.
    pub bytes: u64,
}

impl ByteCounter {
    /// Count an f32 matrix of the given shape.
    pub fn add_matrix_f32(&mut self, rows: usize, cols: usize) {
        self.bytes += (rows as u64) * (cols as u64) * 4;
    }

    /// Count an f32 vector of length n.
    pub fn add_vector_f32(&mut self, n: usize) {
        self.bytes += n as u64 * 4;
    }

    /// Accumulated mebibytes.
    pub fn mib(&self) -> f64 {
        self.bytes as f64 / (1024.0 * 1024.0)
    }
}

/// Dense kernel-matrix bytes for n observed points (f32).
pub fn dense_kernel_bytes(n: usize) -> u64 {
    (n as u64) * (n as u64) * 4
}

/// Latent-Kronecker kernel bytes for a p x q grid (f32).
pub fn kron_kernel_bytes(p: usize, q: usize) -> u64 {
    ((p as u64) * (p as u64) + (q as u64) * (q as u64)) * 4
}

/// Peak resident set size of this process, in bytes (Linux).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current resident set size of this process, in bytes (Linux).
pub fn current_rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(pages * 4096)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_counts() {
        assert_eq!(dense_kernel_bytes(1000), 4_000_000);
        assert_eq!(kron_kernel_bytes(100, 10), (10_000 + 100) * 4);
        let mut c = ByteCounter::default();
        c.add_matrix_f32(256, 256);
        assert!((c.mib() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn rss_readable() {
        let peak = peak_rss_bytes().unwrap();
        let cur = current_rss_bytes().unwrap();
        assert!(peak > 0 && cur > 0);
        assert!(peak >= cur / 2, "peak {peak} vs cur {cur}");
    }
}
