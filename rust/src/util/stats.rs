//! Metric helpers shared by experiments and tests: RMSE, Gaussian NLL,
//! means/standard errors, and rank aggregation (the "Average Rank"
//! column of the paper's tables).

/// Root mean squared error.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred.iter().zip(target).map(|(p, t)| (p - t) * (p - t)).sum();
    (s / pred.len() as f64).sqrt()
}

/// Mean Gaussian negative log-likelihood with per-point predictive
/// variance (the paper's NLL metric).
pub fn gaussian_nll(mean: &[f64], var: &[f64], target: &[f64]) -> f64 {
    assert_eq!(mean.len(), target.len());
    assert_eq!(var.len(), target.len());
    if mean.is_empty() {
        return 0.0;
    }
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    let s: f64 = mean
        .iter()
        .zip(var)
        .zip(target)
        .map(|((m, v), t)| {
            let v = v.max(1e-12);
            0.5 * (ln2pi + v.ln() + (t - m) * (t - m) / v)
        })
        .sum();
    s / mean.len() as f64
}

/// Arithmetic mean (0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (0 for fewer than two values).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    (variance(xs) / xs.len() as f64).sqrt()
}

/// `mean ± sem` formatted like the paper's tables.
pub fn mean_sem_str(xs: &[f64]) -> String {
    format!("{:.3} ± {:.3}", mean(xs), sem(xs))
}

/// Ranks (1 = best = smallest) with ties sharing the average rank.
pub fn ranks(scores: &[f64]) -> Vec<f64> {
    let n = scores.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_basics() {
        assert!((rmse(&[1.0, 2.0], &[1.0, 2.0])).abs() < 1e-12);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn nll_matches_closed_form() {
        // N(0,1) evaluated at 0: 0.5*ln(2*pi)
        let got = gaussian_nll(&[0.0], &[1.0], &[0.0]);
        assert!((got - 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
        // wrong confident prediction is penalized more than wide one
        let tight = gaussian_nll(&[0.0], &[0.01], &[1.0]);
        let wide = gaussian_nll(&[0.0], &[1.0], &[1.0]);
        assert!(tight > wide);
    }

    #[test]
    fn rank_with_ties() {
        assert_eq!(ranks(&[0.1, 0.3, 0.1, 0.9]), vec![1.5, 3.0, 1.5, 4.0]);
    }

    #[test]
    fn sem_decreases_with_n() {
        let a = sem(&[1.0, 2.0, 3.0, 4.0]);
        let b = sem(&[1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
        assert!(b < a);
    }
}
