//! Wall-clock instrumentation: scoped timers and a named-phase profile
//! accumulator used by the perf pass (EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple scoped stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates wall time per named phase; prints a profile table.
#[derive(Default, Debug, Clone)]
pub struct Profile {
    acc: BTreeMap<String, (Duration, u64)>,
}

impl Profile {
    /// Empty profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    /// Add a duration sample under `name`.
    pub fn add(&mut self, name: &str, d: Duration) {
        let e = self.acc.entry(name.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Accumulated seconds under `name` (0 if never timed).
    pub fn secs(&self, name: &str) -> f64 {
        self.acc.get(name).map(|(d, _)| d.as_secs_f64()).unwrap_or(0.0)
    }

    /// Sum over all phases.
    pub fn total_secs(&self) -> f64 {
        self.acc.values().map(|(d, _)| d.as_secs_f64()).sum()
    }

    /// Fold another profile's phases into this one.
    pub fn merge(&mut self, other: &Profile) {
        for (k, (d, n)) in &other.acc {
            let e = self.acc.entry(k.clone()).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += n;
        }
    }

    /// Render as an aligned text table, descending by total time.
    pub fn render(&self) -> String {
        let mut rows: Vec<_> = self.acc.iter().collect();
        rows.sort_by(|a, b| b.1 .0.cmp(&a.1 .0));
        let total = self.total_secs().max(1e-12);
        let mut out = format!("{:<28} {:>10} {:>8} {:>7}\n", "phase", "secs", "calls", "%");
        for (name, (d, n)) in rows {
            let s = d.as_secs_f64();
            out += &format!("{:<28} {:>10.4} {:>8} {:>6.1}%\n", name, s, n, 100.0 * s / total);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_phases() {
        let mut p = Profile::new();
        let x: u64 = p.time("work", || (0..1000).sum());
        assert_eq!(x, 499500);
        p.add("work", Duration::from_millis(1));
        assert!(p.secs("work") > 0.0);
        assert!(p.render().contains("work"));
    }

    #[test]
    fn merge_sums() {
        let mut a = Profile::new();
        a.add("x", Duration::from_millis(2));
        let mut b = Profile::new();
        b.add("x", Duration::from_millis(3));
        a.merge(&b);
        assert!((a.secs("x") - 0.005).abs() < 1e-9);
    }
}
