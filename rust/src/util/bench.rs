//! Benchmark framework for `cargo bench` targets (harness = false;
//! criterion is not in the offline crate set).
//!
//! Auto-calibrates iteration counts to a target measurement time,
//! reports median / mean / p10-p90 across samples, and supports the
//! throughput annotations the MVM benches use (FLOP/s, bytes).

use std::time::{Duration, Instant};

use super::json::Json;

/// One benchmark measurement result.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Median nanoseconds per call across samples.
    pub median_ns: f64,
    /// Mean nanoseconds per call.
    pub mean_ns: f64,
    /// 10th-percentile nanoseconds per call.
    pub p10_ns: f64,
    /// 90th-percentile nanoseconds per call.
    pub p90_ns: f64,
    /// Number of timed samples.
    pub samples: usize,
    /// FLOPs per call, when the caller annotated throughput.
    pub flops: Option<f64>,
    /// worker threads in effect (`crate::par`) when the measurement ran
    pub threads: usize,
}

impl Measurement {
    /// Median seconds per call.
    pub fn secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// One human-readable report line.
    pub fn report(&self) -> String {
        let human = |ns: f64| {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        };
        let mut line = format!(
            "{:<44} {:>12} (mean {:>12}, p10 {:>12}, p90 {:>12}, n={}, t={})",
            self.name,
            human(self.median_ns),
            human(self.mean_ns),
            human(self.p10_ns),
            human(self.p90_ns),
            self.samples,
            self.threads,
        );
        if let Some(f) = self.flops {
            line += &format!("  [{:.2} GFLOP/s]", f / self.secs() / 1e9);
        }
        line
    }
}

/// Bench runner with a global time budget per measurement.
pub struct Bencher {
    /// Target wall time per sample (inner iterations auto-calibrate).
    pub sample_target: Duration,
    /// Samples per measurement.
    pub samples: usize,
    /// Completed measurements, in run order.
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            sample_target: Duration::from_millis(200),
            samples: 7,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    /// Short-budget bencher (smoke mode).
    pub fn quick() -> Self {
        Bencher { sample_target: Duration::from_millis(60), samples: 3, results: Vec::new() }
    }

    /// Measure `f`, auto-calibrating inner iterations.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        self.bench_with_flops(name, None, move || {
            black_box(f());
        })
    }

    /// Measure with a FLOP count per call for throughput reporting.
    pub fn bench_with_flops(
        &mut self,
        name: &str,
        flops: Option<f64>,
        mut f: impl FnMut(),
    ) -> &Measurement {
        // calibrate: how many inner iters fit the sample target?
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = (self.sample_target.as_nanos() / once.as_nanos()).clamp(1, 1_000_000) as u64;

        let mut times: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let pick = |q: f64| times[((times.len() - 1) as f64 * q).round() as usize];
        let m = Measurement {
            name: name.to_string(),
            median_ns: pick(0.5),
            mean_ns: times.iter().sum::<f64>() / times.len() as f64,
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            samples: self.samples,
            flops,
            threads: crate::par::num_threads(),
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().expect("measure() pushed a result above")
    }

    /// Write all results as CSV under results/bench/.
    pub fn save_csv(&self, stem: &str) {
        let dir = std::path::Path::new("results/bench");
        let _ = std::fs::create_dir_all(dir);
        let mut csv = String::from("name,median_ns,mean_ns,p10_ns,p90_ns,samples,threads\n");
        for m in &self.results {
            csv += &format!(
                "{},{},{},{},{},{},{}\n",
                m.name, m.median_ns, m.mean_ns, m.p10_ns, m.p90_ns, m.samples, m.threads
            );
        }
        let _ = std::fs::write(dir.join(format!("{stem}.csv")), csv);
    }

    /// All results as a JSON array — the machine-readable companion of
    /// the printed table (threads and achieved GFLOP/s included).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.results
                .iter()
                .map(|m| {
                    let mut kv = vec![
                        ("name", Json::Str(m.name.clone())),
                        ("median_ns", Json::Num(m.median_ns)),
                        ("mean_ns", Json::Num(m.mean_ns)),
                        ("p10_ns", Json::Num(m.p10_ns)),
                        ("p90_ns", Json::Num(m.p90_ns)),
                        ("samples", Json::Num(m.samples as f64)),
                        ("threads", Json::Num(m.threads as f64)),
                    ];
                    if let Some(fl) = m.flops {
                        kv.push(("flops", Json::Num(fl)));
                        kv.push(("gflops_per_s", Json::Num(fl / m.secs() / 1e9)));
                    }
                    Json::obj(kv)
                })
                .collect(),
        )
    }

    /// Write results as JSON to an explicit path.
    pub fn save_json_to(&self, path: &std::path::Path) {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        let _ = std::fs::write(path, format!("{}\n", self.to_json()));
    }

    /// Write results as JSON under results/bench/ (next to the CSV).
    pub fn save_json(&self, stem: &str) {
        self.save_json_to(&std::path::Path::new("results/bench").join(format!("{stem}.json")));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_includes_threads_and_gflops() {
        let mut b = Bencher::quick();
        b.bench_with_flops("with-flops", Some(1e6), || {
            black_box((0..100u64).sum::<u64>());
        });
        let parsed = crate::util::json::Json::parse(&b.to_json().to_string()).unwrap();
        let first = &parsed.as_arr().unwrap()[0];
        assert!(first.get("threads").and_then(|t| t.as_f64()).unwrap() >= 1.0);
        assert!(first.get("gflops_per_s").is_some());
    }

    #[test]
    fn measures_something() {
        let mut b = Bencher::quick();
        let m = b.bench("noop-sum", || (0..100u64).sum::<u64>());
        assert!(m.median_ns > 0.0);
        assert!(m.p10_ns <= m.p90_ns);
    }

    #[test]
    fn ordering_sane() {
        let mut b = Bencher::quick();
        let fast = b.bench("fast", || black_box(3u64) * 7).median_ns;
        // black_box the bound so release builds cannot const-fold the loop
        let slow = b
            .bench("slow", || {
                (0..black_box(20_000u64)).fold(0u64, |a, x| a.wrapping_add(x * x))
            })
            .median_ns;
        assert!(slow > fast, "slow={slow} fast={fast}");
    }
}
