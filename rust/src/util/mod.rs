//! Hand-rolled substrate utilities.
//!
//! The offline vendored crate set has no serde/clap/criterion/proptest/
//! rand, so the pieces this framework needs are implemented here:
//! a counter-based RNG, a JSON codec, a CLI argument parser, a markdown/
//! CSV table writer, wall-clock + peak-memory instrumentation, a mini
//! property-testing harness, and a benchmark framework used by
//! `cargo bench` targets (harness = false).

pub mod bench;
pub mod cli;
pub mod convert;
pub mod failpoint;
pub mod json;
pub mod mem;
pub mod rng;
pub mod stats;
pub mod table;
pub mod testing;
pub mod timer;
pub mod wire;
