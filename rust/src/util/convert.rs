//! The crate's single f64 -> f32 rounding point.
//!
//! Every boundary that narrows f64 values to f32 — the PJRT tensor
//! boundary (`runtime::TensorF32`), the dense-baseline f32 Gram gather
//! in `gp::backend`, and the mixed-precision (`Precision::F32`) compute
//! path — goes through these helpers so the rounding behaviour (IEEE
//! round-to-nearest-even, the semantics of Rust's `as f32`) is defined
//! in exactly one place. If the narrowing policy ever changes (e.g.
//! stochastic rounding experiments), it changes here for every layer at
//! once.

/// Narrow one f64 to f32 (IEEE round-to-nearest-even).
#[inline]
pub fn f32_of(x: f64) -> f32 {
    x as f32
}

/// Narrow a slice of f64 to a fresh f32 vector.
pub fn f32_vec(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| f32_of(x)).collect()
}

/// Widen a slice of f32 to a fresh f64 vector (exact; every f32 is
/// representable as f64).
pub fn f64_vec(xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| x as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_exact_for_f32_values() {
        let xs = vec![0.5f32, -1.25, 3.0e7, f32::MIN_POSITIVE];
        let wide = f64_vec(&xs);
        let back = f32_vec(&wide);
        assert_eq!(xs, back);
    }

    #[test]
    fn narrowing_matches_as_cast() {
        for &x in &[0.1f64, -1.0 / 3.0, 1e300, -1e-300, 0.0] {
            assert_eq!(f32_of(x).to_bits(), (x as f32).to_bits());
        }
        assert_eq!(f32_vec(&[0.1, 0.2]), vec![0.1f64 as f32, 0.2f64 as f32]);
    }
}
