//! Length-prefixed binary wire protocol for the `lkgp serve` daemon.
//!
//! The serve protocol is deliberately minimal and dependency-free,
//! mirroring the checkpoint codec in `model::io`: every multi-byte
//! value is little-endian regardless of host byte order, every payload
//! carries an FNV-1a 64 integrity trailer, and decoding is **total** —
//! malformed, truncated, corrupted, or oversized input is rejected with
//! a typed [`WireError`], never a panic and never an unbounded
//! allocation. The byte-exact specification lives in `docs/formats.md`
//! (wire-protocol section); this module is its implementation.
//!
//! # Framing
//!
//! Each direction of a connection carries a sequence of *frames*:
//!
//! ```text
//! [0..4)  payload length N, u32 LE (bounded by the reader's max)
//! [4..4+N) payload bytes
//! ```
//!
//! [`read_frame`] validates the length prefix against its `max_bytes`
//! bound *before* allocating, so a hostile or corrupted prefix (e.g.
//! `0xFFFF_FFFF`) yields [`WireError::Oversized`] instead of an
//! allocation attempt. A connection that closes cleanly between frames
//! reads as `Ok(None)`; one that dies mid-frame is a typed
//! [`WireError::Truncated`].
//!
//! # Payloads
//!
//! Requests and responses share a common header (magic, version, kind
//! tag, request id) followed by a kind-specific body and the checksum
//! trailer — see [`Request`] / [`Response`] and the encode/decode
//! functions. The request id is an opaque `u64` chosen by the client
//! and echoed verbatim in the matching response, which is what lets
//! clients pipeline many requests per connection (the daemon answers
//! each connection's requests in arrival order, so ids double as a
//! client-side sanity check).

use std::fmt;
use std::io::{Read, Write};

use crate::model::io::fnv64;
use crate::util::failpoint::{self, FaultAction};

/// First 4 payload bytes of every request.
pub const REQ_MAGIC: [u8; 4] = *b"LKRQ";
/// First 4 payload bytes of every response.
pub const RESP_MAGIC: [u8; 4] = *b"LKRS";
/// Current (and only) wire-protocol version.
pub const WIRE_VERSION: u8 = 1;
/// Default upper bound on a single frame's payload, in bytes. A length
/// prefix above the reader's bound is rejected *before* any allocation.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Typed wire-protocol failure. Every malformed input maps to one of
/// these variants — encoding and decoding never panic.
#[derive(Debug, PartialEq, Eq)]
pub enum WireError {
    /// The frame's length prefix exceeds the reader's bound.
    Oversized {
        /// Length announced by the prefix.
        len: usize,
        /// The reader's configured bound.
        max: usize,
    },
    /// The input ended before a field could be read in full.
    Truncated {
        /// What was being read when the input ran out.
        what: &'static str,
        /// Bytes the field needed.
        needed: usize,
        /// Bytes actually available.
        available: usize,
    },
    /// The payload does not start with the expected magic bytes.
    BadMagic {
        /// The 4 bytes actually found.
        found: [u8; 4],
        /// The magic expected ([`REQ_MAGIC`] or [`RESP_MAGIC`]).
        expected: [u8; 4],
    },
    /// The protocol version is not one this build speaks.
    UnsupportedVersion {
        /// Version tag found in the payload.
        found: u8,
        /// Version this build supports ([`WIRE_VERSION`]).
        supported: u8,
    },
    /// The trailing FNV-1a checksum does not match the payload.
    ChecksumMismatch {
        /// Checksum stored in the trailer.
        stored: u64,
        /// Checksum computed over the payload content.
        computed: u64,
    },
    /// A structurally valid field carries an invalid value (unknown
    /// kind tag, bad UTF-8, count/length mismatch, trailing bytes ...).
    BadField {
        /// Field name.
        what: &'static str,
        /// Human-readable description of the problem.
        detail: String,
    },
    /// The underlying transport failed mid-frame (socket error,
    /// injected `serve_frame` fault).
    Io {
        /// What the transport reported.
        detail: String,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: length prefix {len} exceeds the {max}-byte bound")
            }
            WireError::Truncated { what, needed, available } => {
                write!(f, "truncated frame: {what} needs {needed} bytes, {available} left")
            }
            WireError::BadMagic { found, expected } => {
                write!(f, "bad wire magic {found:?} (expected {expected:?})")
            }
            WireError::UnsupportedVersion { found, supported } => {
                write!(f, "unsupported wire version {found} (this build speaks {supported})")
            }
            WireError::ChecksumMismatch { stored, computed } => write!(
                f,
                "wire checksum mismatch: trailer {stored:#018x}, content {computed:#018x}"
            ),
            WireError::BadField { what, detail } => write!(f, "bad wire field {what}: {detail}"),
            WireError::Io { detail } => write!(f, "wire transport error: {detail}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One client request frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Predict the given grid cells of one model. `model` may be empty
    /// when exactly one model is loaded.
    Predict {
        /// Client-chosen id echoed in the matching response.
        id: u64,
        /// Model id (checkpoint stem) the cells refer to.
        model: String,
        /// Grid cells to predict (layout `j*q + k`, duplicates allowed).
        cells: Vec<usize>,
    },
    /// Liveness / discovery probe; answered immediately (never batched)
    /// with a [`Response::Info`] describing the loaded models.
    Ping {
        /// Client-chosen id echoed in the matching response.
        id: u64,
    },
    /// Ask the daemon to stop accepting connections and exit cleanly.
    Shutdown {
        /// Client-chosen id echoed in the matching response.
        id: u64,
    },
}

impl Request {
    /// The client-chosen request id.
    pub fn id(&self) -> u64 {
        match self {
            Request::Predict { id, .. } | Request::Ping { id } | Request::Shutdown { id } => *id,
        }
    }
}

/// One daemon response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Predictions for a [`Request::Predict`], aligned with its cells.
    Predict {
        /// Echo of the request id.
        id: u64,
        /// Predictive means in raw target scale.
        mean: Vec<f64>,
        /// Predictive variances (including observation noise).
        var: Vec<f64>,
    },
    /// Server description answering a [`Request::Ping`].
    Info {
        /// Echo of the request id.
        id: u64,
        /// Human-readable model listing.
        info: String,
    },
    /// Acknowledgement of a [`Request::Shutdown`], written before the
    /// daemon exits.
    ShutdownAck {
        /// Echo of the request id.
        id: u64,
    },
    /// Typed per-request failure (unknown model, out-of-range cell,
    /// malformed frame ...). The connection stays usable unless the
    /// error was a framing-level one (see `docs/serve.md`).
    Error {
        /// Echo of the request id (0 when the request never decoded).
        id: u64,
        /// What went wrong.
        message: String,
    },
}

impl Response {
    /// The echoed request id.
    pub fn id(&self) -> u64 {
        match self {
            Response::Predict { id, .. }
            | Response::Info { id, .. }
            | Response::ShutdownAck { id }
            | Response::Error { id, .. } => *id,
        }
    }
}

const KIND_PREDICT: u8 = 0;
const KIND_PING: u8 = 1;
const KIND_SHUTDOWN: u8 = 2;

const STATUS_PREDICT: u8 = 0;
const STATUS_INFO: u8 = 1;
const STATUS_SHUTDOWN_ACK: u8 = 2;
const STATUS_ERROR: u8 = 3;

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn seal(mut out: Vec<u8>) -> Vec<u8> {
    let h = fnv64(&out);
    put_u64(&mut out, h);
    out
}

/// Encode a request payload (framing prefix not included — see
/// [`write_frame`]).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&REQ_MAGIC);
    out.push(WIRE_VERSION);
    match req {
        Request::Predict { id, model, cells } => {
            out.push(KIND_PREDICT);
            put_u64(&mut out, *id);
            put_str(&mut out, model);
            put_u32(&mut out, cells.len() as u32);
            for &c in cells {
                put_u64(&mut out, c as u64);
            }
        }
        Request::Ping { id } => {
            out.push(KIND_PING);
            put_u64(&mut out, *id);
        }
        Request::Shutdown { id } => {
            out.push(KIND_SHUTDOWN);
            put_u64(&mut out, *id);
        }
    }
    seal(out)
}

/// Encode a response payload (framing prefix not included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&RESP_MAGIC);
    out.push(WIRE_VERSION);
    match resp {
        Response::Predict { id, mean, var } => {
            out.push(STATUS_PREDICT);
            put_u64(&mut out, *id);
            put_u32(&mut out, mean.len() as u32);
            for &x in mean {
                put_u64(&mut out, x.to_bits());
            }
            for &x in var {
                put_u64(&mut out, x.to_bits());
            }
        }
        Response::Info { id, info } => {
            out.push(STATUS_INFO);
            put_u64(&mut out, *id);
            put_str(&mut out, info);
        }
        Response::ShutdownAck { id } => {
            out.push(STATUS_SHUTDOWN_ACK);
            put_u64(&mut out, *id);
        }
        Response::Error { id, message } => {
            out.push(STATUS_ERROR);
            put_u64(&mut out, *id);
            put_str(&mut out, message);
        }
    }
    seal(out)
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

/// Bounds-checked reader over a payload slice.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        let available = self.b.len() - self.i;
        if n > available {
            return Err(WireError::Truncated { what, needed: n, available });
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn string(&mut self, what: &'static str) -> Result<String, WireError> {
        let len = self.u32(what)? as usize;
        let s = self.take(len, what)?;
        String::from_utf8(s.to_vec())
            .map_err(|e| WireError::BadField { what, detail: format!("invalid UTF-8: {e}") })
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
}

/// Verify the common header + checksum trailer and return a cursor over
/// the body (everything between the version byte and the trailer).
fn open_payload<'a>(
    payload: &'a [u8],
    expected_magic: [u8; 4],
) -> Result<(u8, Cursor<'a>), WireError> {
    // magic + version + kind + trailer is the smallest legal payload
    let min = 4 + 1 + 1 + 8;
    if payload.len() < min {
        return Err(WireError::Truncated {
            what: "payload header",
            needed: min,
            available: payload.len(),
        });
    }
    let mut found = [0u8; 4];
    found.copy_from_slice(&payload[..4]);
    if found != expected_magic {
        return Err(WireError::BadMagic { found, expected: expected_magic });
    }
    let version = payload[4];
    if version != WIRE_VERSION {
        return Err(WireError::UnsupportedVersion { found: version, supported: WIRE_VERSION });
    }
    let content_len = payload.len() - 8;
    let stored = u64::from_le_bytes(
        payload[content_len..].try_into().unwrap_or([0u8; 8]), // length checked above
    );
    let computed = fnv64(&payload[..content_len]);
    if stored != computed {
        return Err(WireError::ChecksumMismatch { stored, computed });
    }
    let kind = payload[5];
    Ok((kind, Cursor { b: &payload[..content_len], i: 6 }))
}

/// Require the cursor fully consumed (trailing bytes mean the payload
/// lies about its own structure).
fn finish(c: Cursor<'_>, what: &'static str) -> Result<(), WireError> {
    if c.remaining() != 0 {
        return Err(WireError::BadField {
            what,
            detail: format!("{} trailing bytes after the last field", c.remaining()),
        });
    }
    Ok(())
}

/// Decode a request payload. Total: every malformed input is a typed
/// [`WireError`]; allocation is bounded by the payload length (counts
/// are validated against the remaining bytes before any `Vec` grows).
pub fn decode_request(payload: &[u8]) -> Result<Request, WireError> {
    let (kind, mut c) = open_payload(payload, REQ_MAGIC)?;
    let req = match kind {
        KIND_PREDICT => {
            let id = c.u64("request id")?;
            let model = c.string("model id")?;
            let count = c.u32("cell count")? as usize;
            let needed = count.checked_mul(8).ok_or(WireError::BadField {
                what: "cell count",
                detail: "cell count overflows".to_string(),
            })?;
            if needed > c.remaining() {
                return Err(WireError::Truncated {
                    what: "cells",
                    needed,
                    available: c.remaining(),
                });
            }
            let mut cells = Vec::with_capacity(count);
            for _ in 0..count {
                let raw = c.u64("cell index")?;
                let cell = usize::try_from(raw).map_err(|_| WireError::BadField {
                    what: "cell index",
                    detail: format!("{raw} does not fit this platform's usize"),
                })?;
                cells.push(cell);
            }
            Request::Predict { id, model, cells }
        }
        KIND_PING => Request::Ping { id: c.u64("request id")? },
        KIND_SHUTDOWN => Request::Shutdown { id: c.u64("request id")? },
        other => {
            return Err(WireError::BadField {
                what: "request kind",
                detail: format!("unknown kind tag {other}"),
            })
        }
    };
    finish(c, "request body")?;
    Ok(req)
}

/// Decode a response payload (same totality guarantees as
/// [`decode_request`]).
pub fn decode_response(payload: &[u8]) -> Result<Response, WireError> {
    let (status, mut c) = open_payload(payload, RESP_MAGIC)?;
    let resp = match status {
        STATUS_PREDICT => {
            let id = c.u64("response id")?;
            let count = c.u32("value count")? as usize;
            let needed = count.checked_mul(16).ok_or(WireError::BadField {
                what: "value count",
                detail: "value count overflows".to_string(),
            })?;
            if needed > c.remaining() {
                return Err(WireError::Truncated {
                    what: "mean/var values",
                    needed,
                    available: c.remaining(),
                });
            }
            let mut mean = Vec::with_capacity(count);
            for _ in 0..count {
                mean.push(f64::from_bits(c.u64("mean value")?));
            }
            let mut var = Vec::with_capacity(count);
            for _ in 0..count {
                var.push(f64::from_bits(c.u64("var value")?));
            }
            Response::Predict { id, mean, var }
        }
        STATUS_INFO => {
            let id = c.u64("response id")?;
            let info = c.string("info string")?;
            Response::Info { id, info }
        }
        STATUS_SHUTDOWN_ACK => Response::ShutdownAck { id: c.u64("response id")? },
        STATUS_ERROR => {
            let id = c.u64("response id")?;
            let message = c.string("error message")?;
            Response::Error { id, message }
        }
        other => {
            return Err(WireError::BadField {
                what: "response status",
                detail: format!("unknown status tag {other}"),
            })
        }
    };
    finish(c, "response body")?;
    Ok(resp)
}

// ---------------------------------------------------------------------
// framing over a transport
// ---------------------------------------------------------------------

/// Read one frame's payload from `r`.
///
/// Returns `Ok(None)` when the peer closed the connection cleanly at a
/// frame boundary, `Ok(Some(payload))` on success, and a typed
/// [`WireError`] for everything else: a length prefix above `max_bytes`
/// is rejected **before allocating** ([`WireError::Oversized`]), a
/// connection dying mid-frame is [`WireError::Truncated`], and a
/// transport error (including a fault injected at the `serve_frame`
/// failpoint) is [`WireError::Io`].
pub fn read_frame(r: &mut impl Read, max_bytes: usize) -> Result<Option<Vec<u8>>, WireError> {
    match failpoint::check("serve_frame") {
        Some(FaultAction::Short | FaultAction::Torn) => {
            // simulate a peer that died mid-frame
            return Err(WireError::Truncated { what: "frame payload", needed: 1, available: 0 });
        }
        Some(_) => {
            return Err(WireError::Io {
                detail: "injected fault at failpoint serve_frame (Error)".to_string(),
            });
        }
        None => {}
    }
    let mut prefix = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut prefix[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean close between frames
                }
                return Err(WireError::Truncated {
                    what: "frame length prefix",
                    needed: 4,
                    available: got,
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io { detail: e.to_string() }),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max_bytes {
        return Err(WireError::Oversized { len, max: max_bytes });
    }
    let mut payload = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        match r.read(&mut payload[filled..]) {
            Ok(0) => {
                return Err(WireError::Truncated {
                    what: "frame payload",
                    needed: len,
                    available: filled,
                })
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io { detail: e.to_string() }),
        }
    }
    Ok(Some(payload))
}

/// Write one frame (length prefix + payload) to `w` without flushing —
/// callers batch multiple frames into one flush where it matters (the
/// daemon's per-connection response coalescing).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), WireError> {
    let len = u32::try_from(payload.len()).map_err(|_| WireError::Oversized {
        len: payload.len(),
        max: u32::MAX as usize,
    })?;
    w.write_all(&len.to_le_bytes()).map_err(|e| WireError::Io { detail: e.to_string() })?;
    w.write_all(payload).map_err(|e| WireError::Io { detail: e.to_string() })?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip_req(req: Request) {
        let bytes = encode_request(&req);
        assert_eq!(decode_request(&bytes).expect("roundtrip"), req);
    }

    fn roundtrip_resp(resp: Response) {
        let bytes = encode_response(&resp);
        assert_eq!(decode_response(&bytes).expect("roundtrip"), resp);
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Predict {
            id: 7,
            model: "climate".to_string(),
            cells: vec![0, 41, 41, usize::from(u16::MAX)],
        });
        roundtrip_req(Request::Predict { id: 0, model: String::new(), cells: vec![] });
        roundtrip_req(Request::Ping { id: u64::MAX });
        roundtrip_req(Request::Shutdown { id: 3 });
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Predict {
            id: 9,
            mean: vec![1.5, -0.25, f64::MIN_POSITIVE],
            var: vec![0.5, 2.0, 1e-300],
        });
        roundtrip_resp(Response::Info { id: 1, info: "model a: 12 x 6".to_string() });
        roundtrip_resp(Response::ShutdownAck { id: 2 });
        roundtrip_resp(Response::Error { id: 0, message: "unknown model \"x\"".to_string() });
    }

    #[test]
    fn float_bits_survive_the_wire_exactly() {
        // NaNs and negative zero must round-trip bit for bit: the serve
        // determinism contract is stated in bits, not in values
        let vals = vec![f64::NAN, -0.0, f64::INFINITY, -f64::INFINITY, 1.0 / 3.0];
        let resp = Response::Predict { id: 1, mean: vals.clone(), var: vals.clone() };
        let back = decode_response(&encode_response(&resp)).expect("roundtrip");
        match back {
            Response::Predict { mean, var, .. } => {
                for (a, b) in vals.iter().zip(mean.iter()).chain(vals.iter().zip(var.iter())) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn wrong_magic_and_version_are_typed() {
        let bytes = encode_request(&Request::Ping { id: 1 });
        // a response decoder refuses a request payload by magic
        match decode_response(&bytes) {
            Err(WireError::BadMagic { expected, .. }) => assert_eq!(expected, RESP_MAGIC),
            other => panic!("expected BadMagic, got {other:?}"),
        }
        let mut future = bytes.clone();
        future[4] = WIRE_VERSION + 1;
        match decode_request(&future) {
            Err(WireError::UnsupportedVersion { found, supported }) => {
                assert_eq!(found, WIRE_VERSION + 1);
                assert_eq!(supported, WIRE_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let bytes = encode_request(&Request::Predict {
            id: 5,
            model: "m".to_string(),
            cells: vec![1, 2, 3],
        });
        for cut in 0..bytes.len() {
            match decode_request(&bytes[..cut]) {
                Err(_) => {}
                Ok(req) => panic!("truncation to {cut} bytes decoded as {req:?}"),
            }
        }
    }

    #[test]
    fn seeded_bit_flips_are_always_rejected() {
        // the FNV trailer catches every single-bit corruption: a flip in
        // the body changes the computed hash, a flip in the trailer
        // changes the stored one
        let bytes = encode_request(&Request::Predict {
            id: 11,
            model: "fuzz".to_string(),
            cells: (0..32).collect(),
        });
        let mut rng = Rng::new(0x5EEDu64);
        for _ in 0..256 {
            let pos = rng.below(bytes.len());
            let bit = (rng.next_u64() % 8) as u8;
            let mut corrupted = bytes.clone();
            corrupted[pos] ^= 1 << bit;
            assert!(
                decode_request(&corrupted).is_err(),
                "flip of bit {bit} at byte {pos} must be rejected"
            );
        }
        let resp_bytes = encode_response(&Response::Predict {
            id: 11,
            mean: vec![1.0; 16],
            var: vec![2.0; 16],
        });
        for _ in 0..256 {
            let pos = rng.below(resp_bytes.len());
            let bit = (rng.next_u64() % 8) as u8;
            let mut corrupted = resp_bytes.clone();
            corrupted[pos] ^= 1 << bit;
            assert!(decode_response(&corrupted).is_err(), "flip at byte {pos} must be rejected");
        }
    }

    #[test]
    fn lying_counts_never_over_allocate() {
        // hand-build a predict request whose cell count claims far more
        // cells than the payload holds; the decoder must reject it by
        // comparing against the remaining bytes, not trust the count
        let mut body = Vec::new();
        body.extend_from_slice(&REQ_MAGIC);
        body.push(WIRE_VERSION);
        body.push(0); // predict
        body.extend_from_slice(&7u64.to_le_bytes()); // id
        body.extend_from_slice(&0u32.to_le_bytes()); // empty model id
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // preposterous count
        let h = fnv64(&body);
        body.extend_from_slice(&h.to_le_bytes());
        match decode_request(&body) {
            Err(WireError::Truncated { what, .. }) => assert_eq!(what, "cells"),
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocating() {
        let mut input: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0x00];
        match read_frame(&mut input, MAX_FRAME_BYTES) {
            Err(WireError::Oversized { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, MAX_FRAME_BYTES);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn frame_roundtrip_and_clean_close() {
        let payload = encode_request(&Request::Ping { id: 1 });
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).expect("write");
        write_frame(&mut buf, &payload).expect("write");
        let mut r: &[u8] = &buf;
        assert_eq!(read_frame(&mut r, MAX_FRAME_BYTES).expect("frame 1"), Some(payload.clone()));
        assert_eq!(read_frame(&mut r, MAX_FRAME_BYTES).expect("frame 2"), Some(payload));
        assert_eq!(read_frame(&mut r, MAX_FRAME_BYTES).expect("eof"), None);
    }

    #[test]
    fn mid_frame_disconnect_is_truncated() {
        let payload = encode_request(&Request::Ping { id: 1 });
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).expect("write");
        // cut inside the payload
        let mut r: &[u8] = &buf[..buf.len() - 3];
        match read_frame(&mut r, MAX_FRAME_BYTES) {
            Err(WireError::Truncated { what, .. }) => assert_eq!(what, "frame payload"),
            other => panic!("expected Truncated, got {other:?}"),
        }
        // cut inside the length prefix itself
        let mut r: &[u8] = &buf[..2];
        match read_frame(&mut r, MAX_FRAME_BYTES) {
            Err(WireError::Truncated { what, .. }) => assert_eq!(what, "frame length prefix"),
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn seeded_frame_stream_fuzz_never_panics() {
        // arbitrary byte streams through the frame reader + decoder:
        // every outcome is Ok(None) (clean close), a decoded garbage
        // payload is impossible (checksum), or a typed error
        let mut rng = Rng::new(0xF00Du64);
        for round in 0..128 {
            let n = rng.below(200);
            let bytes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let mut r: &[u8] = &bytes;
            loop {
                match read_frame(&mut r, 1 << 16) {
                    Ok(None) => break,
                    Ok(Some(payload)) => {
                        assert!(
                            decode_request(&payload).is_err(),
                            "round {round}: random payload decoded as a request"
                        );
                    }
                    Err(_) => break,
                }
            }
        }
    }
}
