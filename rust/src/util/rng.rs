//! Deterministic pseudo-random number generation.
//!
//! PCG64-style generator (xsl-rr output on a 128-bit LCG) seeded via
//! SplitMix64, plus the distribution helpers the GP stack needs:
//! standard normals (Box–Muller with caching), Rademacher probes,
//! permutations, and subset sampling. No external crates.

/// SplitMix64: used to expand a user seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// PCG XSL-RR 128/64 generator. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (seed also perturbs the stream increment).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let a = splitmix64(&mut sm) as u128;
        let b = splitmix64(&mut sm) as u128;
        let c = splitmix64(&mut sm) as u128;
        let d = splitmix64(&mut sm) as u128;
        let mut rng = Rng {
            state: (a << 64) | b,
            inc: ((c << 64) | d) | 1,
            cached_normal: None,
        };
        rng.next_u64(); // warm up
        rng
    }

    /// Derive an independent child stream (for parallel workers/tasks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(0x2360ED051FC65DA44385DF649FCCF645)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (second value cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of f32 standard normals (PJRT boundary convenience).
    pub fn normals_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Rademacher (+1/-1) probe vector, as f32.
    pub fn rademacher_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Choose k distinct indices out of n (k <= n), unsorted.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_give_distinct_streams() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(7);
        let xs: Vec<f64> = (0..20000).map(|_| rng.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let xs = rng.normals(40000);
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct() {
        let mut rng = Rng::new(5);
        let mut got = rng.choose(100, 30);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 30);
    }

    #[test]
    fn rademacher_balanced() {
        let mut rng = Rng::new(9);
        let v = rng.rademacher_f32(10000);
        let sum: f32 = v.iter().sum();
        assert!(sum.abs() < 300.0);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
    }
}
