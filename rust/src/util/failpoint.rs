//! Deterministic fault-injection failpoints.
//!
//! A *failpoint* is a named site in the code (e.g. `backend_mvm`,
//! `ckpt_write`) that normally does nothing. When armed through the
//! `LKGP_FAILPOINTS` environment variable (or programmatically via
//! [`with_failpoints`] in tests) it fires a configured [`FaultAction`]
//! that the surrounding code translates into a realistic failure: a
//! typed backend error, a NaN in a CG iterate, a torn checkpoint write,
//! a panicking parallel-region chunk.
//!
//! # Grammar
//!
//! ```text
//! LKGP_FAILPOINTS = spec [ ';' spec ]*
//! spec            = site [ '@' N ] ':' action
//! action          = error | nan | panic | torn | short | bitflip
//! ```
//!
//! * `site` names the failpoint (see `docs/robustness.md` for the list).
//! * `@N` fires only on the N-th *hit* of that site (0-based, counted
//!   process-wide across the failpoint's lifetime); without `@N` the
//!   spec fires on every hit.
//! * Example: `backend_mvm@3:error;ckpt_write:torn` — the fourth backend
//!   MVM fails with a typed error, and every checkpoint write is torn.
//!
//! # Determinism
//!
//! Hit counting is the only state: no clocks, no RNG, no thread
//! identity. Sites are placed where the serial order of hits is fixed by
//! the bit-determinism contract (dispatch points, not per-chunk work),
//! so a given spec injects the same fault at the same logical step at
//! any `LKGP_THREADS`.
//!
//! # Cost when disarmed
//!
//! [`check`] is a single relaxed atomic load when no failpoints are
//! configured — safe to leave in hot paths.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// What an armed failpoint injects when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Return a typed error from the instrumented operation.
    Error,
    /// Poison the operation's numeric output with a NaN.
    Nan,
    /// Panic inside the instrumented region (exercises panic capture).
    Panic,
    /// Truncate a file write partway through (crash-consistency).
    Torn,
    /// Truncate a file read partway through.
    Short,
    /// Flip one bit/byte of an IO buffer (silent corruption).
    BitFlip,
}

impl FaultAction {
    fn parse(tok: &str) -> Result<Self, String> {
        match tok {
            "error" => Ok(FaultAction::Error),
            "nan" => Ok(FaultAction::Nan),
            "panic" => Ok(FaultAction::Panic),
            "torn" => Ok(FaultAction::Torn),
            "short" => Ok(FaultAction::Short),
            "bitflip" => Ok(FaultAction::BitFlip),
            _ => Err(format!(
                "unknown failpoint action {tok:?} (expected error|nan|panic|torn|short|bitflip)"
            )),
        }
    }
}

/// A typed error representing a fault injected at a failpoint.
///
/// Instrumented operations that fail with [`FaultAction::Error`] wrap
/// this in their usual error type so the rest of the stack exercises
/// its real error paths; tests downcast through the anyhow chain to
/// verify the fault propagated as a typed error rather than a panic.
#[derive(Clone, Debug)]
pub struct InjectedFault {
    /// Failpoint site that fired.
    pub site: String,
    /// Action that was injected.
    pub action: FaultAction,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected fault at failpoint {} ({:?})", self.site, self.action)
    }
}

impl std::error::Error for InjectedFault {}

/// One parsed `site[@N]:action` spec plus its hit counter.
struct FailSpec {
    site: String,
    nth: Option<u64>,
    action: FaultAction,
    hits: u64,
}

const UNINIT: u8 = 0;
const DISARMED: u8 = 1;
const ARMED: u8 = 2;

/// Fast-path flag: UNINIT until the env var is first consulted, then
/// DISARMED (no specs) or ARMED (at least one spec installed).
static STATE: AtomicU8 = AtomicU8::new(UNINIT);
/// Installed specs; `None` means disarmed.
static SPECS: Mutex<Option<Vec<FailSpec>>> = Mutex::new(None);
/// Serializes `with_failpoints`/`without_failpoints` scopes across test
/// threads so concurrently running tests never see each other's specs.
static SCOPE: Mutex<()> = Mutex::new(());

fn lock_specs() -> std::sync::MutexGuard<'static, Option<Vec<FailSpec>>> {
    SPECS.lock().unwrap_or_else(|e| e.into_inner())
}

/// Parse a full `LKGP_FAILPOINTS` value into specs.
fn parse(s: &str) -> Result<Vec<FailSpec>, String> {
    let mut out = Vec::new();
    for spec in s.split(';').map(str::trim).filter(|t| !t.is_empty()) {
        let (head, action) = spec
            .rsplit_once(':')
            .ok_or_else(|| format!("failpoint spec {spec:?} missing ':action'"))?;
        let action = FaultAction::parse(action.trim())?;
        let (site, nth) = match head.split_once('@') {
            Some((site, n)) => {
                let n: u64 = n
                    .trim()
                    .parse()
                    .map_err(|_| format!("failpoint spec {spec:?}: bad hit index {n:?}"))?;
                (site.trim(), Some(n))
            }
            None => (head.trim(), None),
        };
        if site.is_empty() {
            return Err(format!("failpoint spec {spec:?} has an empty site name"));
        }
        out.push(FailSpec { site: site.to_string(), nth, action, hits: 0 });
    }
    Ok(out)
}

/// Install specs (or disarm with `None`), returning the previous specs.
fn install(specs: Option<Vec<FailSpec>>) -> Option<Vec<FailSpec>> {
    let mut guard = lock_specs();
    let armed = specs.as_ref().map(|v| !v.is_empty()).unwrap_or(false);
    let prev = std::mem::replace(&mut *guard, specs);
    STATE.store(if armed { ARMED } else { DISARMED }, Ordering::Release);
    prev
}

fn init_from_env() {
    let mut guard = lock_specs();
    if STATE.load(Ordering::Acquire) != UNINIT {
        return; // lost the init race; someone else installed
    }
    let specs = match std::env::var("LKGP_FAILPOINTS") {
        Ok(v) if !v.trim().is_empty() => match parse(&v) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("warning: ignoring invalid LKGP_FAILPOINTS: {e}");
                None
            }
        },
        _ => None,
    };
    let armed = specs.as_ref().map(|v| !v.is_empty()).unwrap_or(false);
    *guard = specs;
    STATE.store(if armed { ARMED } else { DISARMED }, Ordering::Release);
}

#[cold]
fn check_slow(site: &str) -> Option<FaultAction> {
    let mut guard = lock_specs();
    let specs = guard.as_mut()?;
    let mut fired = None;
    for spec in specs.iter_mut() {
        if spec.site != site {
            continue;
        }
        let hit = spec.hits;
        spec.hits += 1;
        let fire = match spec.nth {
            Some(n) => hit == n,
            None => true,
        };
        if fire && fired.is_none() {
            fired = Some(spec.action);
        }
    }
    fired
}

/// Consult the failpoint named `site`.
///
/// Returns `Some(action)` when an armed spec fires on this hit and
/// `None` otherwise. Every call counts as one hit of `site` (whether or
/// not a spec fires), so `site@N` specs index the N-th call. Disarmed
/// cost is one relaxed atomic load.
pub fn check(site: &str) -> Option<FaultAction> {
    match STATE.load(Ordering::Relaxed) {
        DISARMED => None,
        ARMED => check_slow(site),
        _ => {
            init_from_env();
            check(site)
        }
    }
}

/// Run `f` with the given failpoint spec string armed, restoring the
/// previous configuration afterwards (even on panic).
///
/// Panics if `spec` does not parse — tests should fail loudly on a bad
/// spec rather than silently running without faults. Scopes are
/// serialized process-wide (failpoints are global state), so concurrent
/// tests queue rather than interfere; do not nest scopes on one thread.
pub fn with_failpoints<T>(spec: &str, f: impl FnOnce() -> T) -> T {
    let specs = parse(spec).unwrap_or_else(|e| panic!("with_failpoints: {e}"));
    scoped(Some(specs), f)
}

/// Run `f` with all failpoints disarmed, restoring the previous
/// configuration afterwards. Use for fault-test baselines that must not
/// see faults armed by a sibling scope or the environment.
pub fn without_failpoints<T>(f: impl FnOnce() -> T) -> T {
    scoped(None, f)
}

fn scoped<T>(specs: Option<Vec<FailSpec>>, f: impl FnOnce() -> T) -> T {
    let _scope = SCOPE.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(Option<Option<Vec<FailSpec>>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            if let Some(prev) = self.0.take() {
                install(prev);
            }
        }
    }
    // Force init first so `prev` reflects the env-derived baseline
    // rather than UNINIT (which install() would misreport as armed).
    if STATE.load(Ordering::Acquire) == UNINIT {
        init_from_env();
    }
    let _restore = Restore(Some(install(specs)));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests use reserved `__fp_test_*` site names that no library
    // code consults, so they cannot perturb concurrently running tests.

    #[test]
    fn disarmed_returns_none() {
        without_failpoints(|| {
            assert_eq!(check("__fp_test_a"), None);
            assert_eq!(check("__fp_test_a"), None);
        });
    }

    #[test]
    fn every_hit_fires_without_index() {
        with_failpoints("__fp_test_b:error", || {
            assert_eq!(check("__fp_test_b"), Some(FaultAction::Error));
            assert_eq!(check("__fp_test_b"), Some(FaultAction::Error));
            assert_eq!(check("__fp_test_other"), None);
        });
    }

    #[test]
    fn nth_hit_fires_once() {
        with_failpoints("__fp_test_c@2:nan", || {
            assert_eq!(check("__fp_test_c"), None);
            assert_eq!(check("__fp_test_c"), None);
            assert_eq!(check("__fp_test_c"), Some(FaultAction::Nan));
            assert_eq!(check("__fp_test_c"), None);
        });
    }

    #[test]
    fn multiple_specs_and_restore() {
        with_failpoints("__fp_test_d:torn; __fp_test_e@0:bitflip", || {
            assert_eq!(check("__fp_test_e"), Some(FaultAction::BitFlip));
            assert_eq!(check("__fp_test_e"), None);
            assert_eq!(check("__fp_test_d"), Some(FaultAction::Torn));
        });
        // scope ended: sites are disarmed again (absent env config)
        without_failpoints(|| {
            assert_eq!(check("__fp_test_d"), None);
        });
    }

    #[test]
    fn parse_errors_are_typed() {
        assert!(parse("no_action_here").is_err());
        assert!(parse("site@x:error").is_err());
        assert!(parse("site:explode").is_err());
        assert!(parse(":error").is_err());
        assert!(parse("").unwrap().is_empty());
        assert!(parse(" ; ").unwrap().is_empty());
    }

    #[test]
    fn injected_fault_display() {
        let e = InjectedFault { site: "backend_mvm".into(), action: FaultAction::Error };
        let s = e.to_string();
        assert!(s.contains("injected fault"), "{s}");
        assert!(s.contains("backend_mvm"), "{s}");
    }
}
