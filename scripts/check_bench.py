#!/usr/bin/env python3
"""Benchmark-regression gate for the bench-smoke CI job.

Reads the machine-readable bench artifacts (BENCH_par.json,
BENCH_precision.json, BENCH_solver.json) and exits non-zero if any
acceptance field regressed:

  BENCH_par.json
    gemm_microkernel.tiled_ge_1p5x   tiled f64 GEMM >= 1.5x scalar matmul_nt
    gemm_microkernel.tiled_f32_ge_2x tiled f32 GEMM >= 2x scalar matmul_nt
    gemm_microkernel.gemm_gflops_ok  tiled GFLOP/s above the emitted floor
    pool.region_speedup_ge_1x        persistent-pool region dispatch no
                                     slower than the scoped-spawn baseline
                                     (>= 10x is the design target; the 1x
                                     gate absorbs noisy shared runners and
                                     pool.dispatch_speedup carries the
                                     measured ratio)
    fit[*].bit_identical             posterior bit-identical per thread count

  also required to be present and numeric in BENCH_par.json:
    pool.dispatch_ns                 empty-region latency on the pool
    pool.steal_ratio                 fraction of steal-mode chunks run by a
                                     non-home worker (work-stealing signal)

  BENCH_precision.json
    speedups_f32_over_f64.mvm_ge_1p5x  f32 Kron MVM >= 1.5x f64
    fig3_accuracy.within_1pct          f32 test RMSE within 1% of f64

  BENCH_solver.json
    eig.iters_reduction_ge_2x        KronEig-preconditioned CG needs at most
                                     half the iterations of pivoted Cholesky
                                     at 5% missingness

  also required to be present and numeric in BENCH_solver.json:
    eig.cg_iters_plain               pivoted-Cholesky CG iterations
    eig.cg_iters_eig_precond         KronEig-preconditioned CG iterations
    eig.full_grid_speedup_vs_cg      direct spectral solve vs CG wall time on
                                     a fully-observed grid (informational)

  BENCH_serve.json
    serve.batched_ge_1x              daemon cross-request batching at least
                                     matches serial per-request dispatch
    serve.wire_bit_identical         every served response bit-equal to the
                                     offline posterior regardless of grouping

  also required to be present and numeric in BENCH_serve.json:
    serve.throughput_batched_rps     batched daemon throughput
    serve.mean_batch_occupancy       predict requests per coalesced sweep
    serve.p50_ms                     median request latency (batched daemon)
    serve.p99_ms                     tail request latency (batched daemon)

  BENCH_toeplitz.json
    toeplitz.mvm_speedup_ge_2x       FFT/Toeplitz time-factor MVM >= 2x the
                                     dense K_TT half-GEMM at q = 4096
    toeplitz.bit_identical_threads   Toeplitz-path Kron apply bit-identical
                                     at 1 and 4 worker threads

  also required to be present and numeric in BENCH_toeplitz.json:
    toeplitz.mvm_speedup             measured FFT-vs-dense speedup
    toeplitz.max_abs_diff_vs_dense   FFT-vs-dense agreement (tolerance-level,
                                     never bit-equal: different rounding)

  BENCH_ski.json
    ski.rmse_within_5pct_of_dense    SKI held-out RMSE within 5% of the dense
                                     exact-GP baseline on the same off-grid
                                     sample
    ski.fit_speedup_ge_2x            SKI fit >= 2x faster than the O(n^3)
                                     dense Cholesky fit
    ski.bit_identical_threads        full SKI fit posterior bit-identical at
                                     1 and 4 worker threads

  also required to be present and numeric in BENCH_ski.json:
    ski.rmse_ski                     SKI held-out RMSE
    ski.rmse_dense                   dense exact-GP held-out RMSE
    ski.fit_speedup                  measured dense-vs-SKI fit speedup

A referenced key that is absent is reported as a named error listing the
keys that *are* available at the deepest resolvable level, so a renamed
bench field fails loudly instead of looking like a regression.

Usage: check_bench.py BENCH_par.json BENCH_precision.json BENCH_solver.json \
       BENCH_serve.json BENCH_toeplitz.json BENCH_ski.json
"""

import json
import sys

GATES = {
    "BENCH_par.json": [
        (("gemm_microkernel", "tiled_ge_1p5x"), "tiled f64 GEMM >= 1.5x scalar matmul_nt"),
        (("gemm_microkernel", "tiled_f32_ge_2x"), "tiled f32 GEMM >= 2x scalar matmul_nt"),
        (("gemm_microkernel", "gemm_gflops_ok"), "tiled GEMM above gemm_gflops_min floor"),
        (("pool", "region_speedup_ge_1x"), "pool region dispatch >= scoped-spawn baseline"),
    ],
    "BENCH_precision.json": [
        (("speedups_f32_over_f64", "mvm_ge_1p5x"), "f32 Kron MVM >= 1.5x f64"),
        (("fig3_accuracy", "within_1pct"), "f32 test RMSE within 1% of f64"),
    ],
    "BENCH_solver.json": [
        (
            ("eig", "iters_reduction_ge_2x"),
            "KronEig precond cuts CG iterations >= 2x vs pivoted Cholesky at 5% missing",
        ),
    ],
    "BENCH_serve.json": [
        (
            ("serve", "batched_ge_1x"),
            "daemon cross-request batching >= serial per-request dispatch",
        ),
        (
            ("serve", "wire_bit_identical"),
            "served responses bit-equal to the offline posterior for any grouping",
        ),
    ],
    "BENCH_toeplitz.json": [
        (
            ("toeplitz", "mvm_speedup_ge_2x"),
            "FFT/Toeplitz time-factor MVM >= 2x dense K_TT half-GEMM at q = 4096",
        ),
        (
            ("toeplitz", "bit_identical_threads"),
            "Toeplitz-path Kron apply bit-identical at 1 and 4 worker threads",
        ),
    ],
    "BENCH_ski.json": [
        (
            ("ski", "rmse_within_5pct_of_dense"),
            "SKI held-out RMSE within 5% of the dense exact-GP baseline",
        ),
        (
            ("ski", "fit_speedup_ge_2x"),
            "SKI fit >= 2x faster than the dense O(n^3) Cholesky fit",
        ),
        (
            ("ski", "bit_identical_threads"),
            "SKI fit posterior bit-identical at 1 and 4 worker threads",
        ),
    ],
}

# numeric metrics that must exist (informational gauges the perf
# trajectory tracks; their absence means the bench section did not run)
REQUIRED_NUMBERS = {
    "BENCH_par.json": [
        (("pool", "dispatch_ns"), "persistent-pool empty-region latency"),
        (("pool", "steal_ratio"), "steal-mode chunk migration ratio"),
    ],
    "BENCH_solver.json": [
        (("eig", "cg_iters_plain"), "pivoted-Cholesky CG iterations"),
        (("eig", "cg_iters_eig_precond"), "KronEig-preconditioned CG iterations"),
        (("eig", "full_grid_speedup_vs_cg"), "direct spectral solve speedup vs CG"),
    ],
    "BENCH_serve.json": [
        (("serve", "throughput_batched_rps"), "batched daemon throughput"),
        (("serve", "mean_batch_occupancy"), "predict requests per coalesced sweep"),
        (("serve", "p50_ms"), "median request latency, batched daemon"),
        (("serve", "p99_ms"), "p99 request latency, batched daemon"),
    ],
    "BENCH_toeplitz.json": [
        (("toeplitz", "mvm_speedup"), "measured FFT-vs-dense time-factor speedup"),
        (("toeplitz", "max_abs_diff_vs_dense"), "FFT-vs-dense MVM agreement"),
    ],
    "BENCH_ski.json": [
        (("ski", "rmse_ski"), "SKI held-out RMSE"),
        (("ski", "rmse_dense"), "dense exact-GP held-out RMSE"),
        (("ski", "fit_speedup"), "measured dense-vs-SKI fit speedup"),
    ],
}


def lookup(doc, path):
    """Resolve a key path. Returns (value, None) on success, or
    (None, error) naming the missing key and listing the keys available
    at the deepest level that did resolve."""
    cur = doc
    for depth, key in enumerate(path):
        if not isinstance(cur, dict):
            where = ".".join(path[:depth]) or "<root>"
            return None, f"'{where}' is not an object (cannot contain {key!r})"
        if key not in cur:
            where = ".".join(path[:depth]) or "<root>"
            avail = ", ".join(sorted(cur.keys())) or "<none>"
            return None, f"missing key {key!r} under '{where}' — available keys: {avail}"
        cur = cur[key]
    return cur, None


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    failures = []
    for fname in argv[1:]:
        base = fname.split("/")[-1]
        try:
            with open(fname) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            failures.append(f"{fname}: unreadable bench artifact ({e})")
            continue
        gates = GATES.get(base)
        if gates is None:
            failures.append(
                f"{fname}: no acceptance gates registered for basename {base!r} "
                "— refusing to pass an unchecked artifact"
            )
            continue
        for path, desc in gates:
            val, err = lookup(doc, path)
            dotted = ".".join(path)
            if err is not None:
                failures.append(f"{fname}: acceptance field {dotted} ({desc}): {err}")
            elif val is not True:
                failures.append(f"{fname}: {dotted} = {val!r} — REGRESSED: {desc}")
            else:
                print(f"ok   {fname}: {dotted} ({desc})")
        for path, desc in REQUIRED_NUMBERS.get(base, []):
            val, err = lookup(doc, path)
            dotted = ".".join(path)
            if err is not None:
                failures.append(f"{fname}: required metric {dotted} ({desc}): {err}")
            elif not isinstance(val, (int, float)) or isinstance(val, bool):
                failures.append(
                    f"{fname}: required metric {dotted} ({desc}) is {val!r}, not a number"
                )
            else:
                print(f"ok   {fname}: {dotted} = {val:.6g} ({desc})")
        if base == "BENCH_par.json":
            fit_rows = doc.get("fit")
            if not isinstance(fit_rows, list) or not fit_rows:
                failures.append(
                    f"{fname}: 'fit' rows missing or empty — the per-thread "
                    "bit_identical gate did not run"
                )
                fit_rows = []
            for row in fit_rows:
                if row.get("bit_identical") is not True:
                    failures.append(
                        f"{fname}: fit row threads={row.get('threads')} "
                        "not bit-identical"
                    )
                else:
                    print(f"ok   {fname}: fit threads={row.get('threads')} bit-identical")
    if failures:
        print()
        for msg in failures:
            print(f"FAIL {msg}", file=sys.stderr)
        return 1
    print("\nall bench acceptance fields green")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
