#!/usr/bin/env python3
"""Benchmark-regression gate for the bench-smoke CI job.

Reads the machine-readable bench artifacts (BENCH_par.json,
BENCH_precision.json) and exits non-zero if any acceptance field
regressed:

  BENCH_par.json
    gemm_microkernel.tiled_ge_1p5x   tiled f64 GEMM >= 1.5x scalar matmul_nt
    gemm_microkernel.tiled_f32_ge_2x tiled f32 GEMM >= 2x scalar matmul_nt
    gemm_microkernel.gemm_gflops_ok  tiled GFLOP/s above the emitted floor
    fit[*].bit_identical             posterior bit-identical per thread count

  BENCH_precision.json
    speedups_f32_over_f64.mvm_ge_1p5x  f32 Kron MVM >= 1.5x f64
    fig3_accuracy.within_1pct          f32 test RMSE within 1% of f64

Usage: check_bench.py BENCH_par.json BENCH_precision.json
"""

import json
import sys

GATES = {
    "BENCH_par.json": [
        (("gemm_microkernel", "tiled_ge_1p5x"), "tiled f64 GEMM >= 1.5x scalar matmul_nt"),
        (("gemm_microkernel", "tiled_f32_ge_2x"), "tiled f32 GEMM >= 2x scalar matmul_nt"),
        (("gemm_microkernel", "gemm_gflops_ok"), "tiled GEMM above gemm_gflops_min floor"),
    ],
    "BENCH_precision.json": [
        (("speedups_f32_over_f64", "mvm_ge_1p5x"), "f32 Kron MVM >= 1.5x f64"),
        (("fig3_accuracy", "within_1pct"), "f32 test RMSE within 1% of f64"),
    ],
}


def lookup(doc, path):
    cur = doc
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return cur


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    failures = []
    for fname in argv[1:]:
        base = fname.split("/")[-1]
        try:
            with open(fname) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            failures.append(f"{fname}: unreadable bench artifact ({e})")
            continue
        gates = GATES.get(base)
        if gates is None:
            failures.append(
                f"{fname}: no acceptance gates registered for basename {base!r} "
                "— refusing to pass an unchecked artifact"
            )
            continue
        for path, desc in gates:
            val = lookup(doc, path)
            dotted = ".".join(path)
            if val is None:
                failures.append(f"{fname}: missing acceptance field {dotted} ({desc})")
            elif val is not True:
                failures.append(f"{fname}: {dotted} = {val!r} — REGRESSED: {desc}")
            else:
                print(f"ok   {fname}: {dotted} ({desc})")
        if base == "BENCH_par.json":
            fit_rows = doc.get("fit")
            if not isinstance(fit_rows, list) or not fit_rows:
                failures.append(
                    f"{fname}: 'fit' rows missing or empty — the per-thread "
                    "bit_identical gate did not run"
                )
                fit_rows = []
            for row in fit_rows:
                if row.get("bit_identical") is not True:
                    failures.append(
                        f"{fname}: fit row threads={row.get('threads')} "
                        "not bit-identical"
                    )
                else:
                    print(f"ok   {fname}: fit threads={row.get('threads')} bit-identical")
    if failures:
        print()
        for msg in failures:
            print(f"FAIL {msg}", file=sys.stderr)
        return 1
    print("\nall bench acceptance fields green")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
