#!/usr/bin/env python3
"""Unit tests for the bench-regression gate (scripts/check_bench.py).

Pure stdlib; CI runs this in the bench-smoke job *before* the real gate
so a broken gate fails the build as loudly as a broken bench:

    python3 -B scripts/test_check_bench.py

Covers: key-path lookup (including the available-keys listing on a
miss), the pass path over synthetic artifacts for every registered
basename, regression / missing-key / non-boolean-gate failures, the
unknown-basename refusal, unreadable artifacts, the BENCH_par per-thread
fit-row branch, and the usage exit code.
"""

import contextlib
import importlib.util
import io
import json
import os
import sys
import tempfile
import unittest

_HERE = os.path.dirname(os.path.abspath(__file__))
_SPEC = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(_HERE, "check_bench.py")
)
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)


def synthetic_artifacts():
    """Minimal artifact documents that satisfy every registered gate."""
    return {
        "BENCH_par.json": {
            "gemm_microkernel": {
                "tiled_ge_1p5x": True,
                "tiled_f32_ge_2x": True,
                "gemm_gflops_ok": True,
            },
            "pool": {
                "region_speedup_ge_1x": True,
                "dispatch_ns": 120.0,
                "steal_ratio": 0.4,
            },
            "fit": [
                {"threads": 1, "bit_identical": True},
                {"threads": 4, "bit_identical": True},
            ],
        },
        "BENCH_precision.json": {
            "speedups_f32_over_f64": {"mvm_ge_1p5x": True},
            "fig3_accuracy": {"within_1pct": True},
        },
        "BENCH_solver.json": {
            "eig": {
                "iters_reduction_ge_2x": True,
                "cg_iters_plain": 40,
                "cg_iters_eig_precond": 11,
                "full_grid_speedup_vs_cg": 3.5,
            },
        },
        "BENCH_serve.json": {
            "serve": {
                "batched_ge_1x": True,
                "wire_bit_identical": True,
                "throughput_batched_rps": 15000.0,
                "mean_batch_occupancy": 6.2,
                "p50_ms": 1.1,
                "p99_ms": 4.0,
            },
        },
        "BENCH_toeplitz.json": {
            "toeplitz": {
                "mvm_speedup_ge_2x": True,
                "bit_identical_threads": True,
                "mvm_speedup": 9.3,
                "max_abs_diff_vs_dense": 2.1e-12,
            },
        },
        "BENCH_ski.json": {
            "ski": {
                "rmse_within_5pct_of_dense": True,
                "fit_speedup_ge_2x": True,
                "bit_identical_threads": True,
                "rmse_ski": 0.146,
                "rmse_dense": 0.142,
                "fit_speedup": 11.8,
            },
        },
    }


@contextlib.contextmanager
def artifact_dir(docs):
    """Write the given {basename: doc} mapping into a temp dir."""
    with tempfile.TemporaryDirectory() as d:
        for base, doc in docs.items():
            with open(os.path.join(d, base), "w") as f:
                json.dump(doc, f)
        yield d


def run_main(docs):
    """Run check_bench.main over the docs; return (exit_code, out, err)."""
    out, err = io.StringIO(), io.StringIO()
    with artifact_dir(docs) as d:
        argv = ["check_bench.py"] + [os.path.join(d, b) for b in sorted(docs)]
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            code = check_bench.main(argv)
    return code, out.getvalue(), err.getvalue()


class LookupTests(unittest.TestCase):
    def test_resolves_nested_path(self):
        val, err = check_bench.lookup({"a": {"b": 7}}, ("a", "b"))
        self.assertEqual(val, 7)
        self.assertIsNone(err)

    def test_missing_key_names_itself_and_lists_available(self):
        val, err = check_bench.lookup({"a": {"x": 1, "y": 2}}, ("a", "b"))
        self.assertIsNone(val)
        self.assertIn("'b'", err)
        self.assertIn("under 'a'", err)
        # a renamed field must list what IS there, so the failure reads
        # as a rename rather than a regression
        self.assertIn("x, y", err)

    def test_missing_top_level_key_reports_root(self):
        _, err = check_bench.lookup({"other": 1}, ("serve", "p50_ms"))
        self.assertIn("<root>", err)
        self.assertIn("other", err)

    def test_non_object_intermediate_is_a_typed_error(self):
        _, err = check_bench.lookup({"a": 42}, ("a", "b"))
        self.assertIn("not an object", err)

    def test_empty_dict_reports_none_available(self):
        _, err = check_bench.lookup({}, ("serve",))
        self.assertIn("<none>", err)


class MainTests(unittest.TestCase):
    def test_all_green_exits_zero(self):
        code, out, err = run_main(synthetic_artifacts())
        self.assertEqual(code, 0, err)
        self.assertIn("all bench acceptance fields green", out)
        # every registered basename produced at least one ok line
        for base in check_bench.GATES:
            self.assertIn(base, out)

    def test_regressed_gate_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_serve.json"]["serve"]["batched_ge_1x"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("REGRESSED", err)
        self.assertIn("serve.batched_ge_1x", err)

    def test_non_boolean_gate_value_fails(self):
        # a gate that is truthy-but-not-True (e.g. a speedup number
        # written where the bool belongs) must not pass
        docs = synthetic_artifacts()
        docs["BENCH_serve.json"]["serve"]["wire_bit_identical"] = 1.7
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("wire_bit_identical", err)

    def test_missing_gate_key_is_a_named_error(self):
        docs = synthetic_artifacts()
        del docs["BENCH_serve.json"]["serve"]["batched_ge_1x"]
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("missing key 'batched_ge_1x'", err)
        self.assertIn("available keys", err)

    def test_missing_required_number_fails(self):
        docs = synthetic_artifacts()
        del docs["BENCH_serve.json"]["serve"]["p99_ms"]
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("p99_ms", err)

    def test_non_numeric_required_metric_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_serve.json"]["serve"]["p50_ms"] = "fast"
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("not a number", err)

    def test_boolean_is_not_a_number(self):
        # bool is an int subclass in Python; the gate must still reject it
        docs = synthetic_artifacts()
        docs["BENCH_serve.json"]["serve"]["p50_ms"] = True
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("not a number", err)

    def test_unknown_basename_is_refused(self):
        docs = synthetic_artifacts()
        docs["BENCH_mystery.json"] = {"whatever": True}
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("no acceptance gates registered", err)

    def test_unreadable_artifact_fails(self):
        with tempfile.TemporaryDirectory() as d:
            bad = os.path.join(d, "BENCH_serve.json")
            with open(bad, "w") as f:
                f.write("{not json")
            out, err = io.StringIO(), io.StringIO()
            with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
                code = check_bench.main(["check_bench.py", bad])
        self.assertEqual(code, 1)
        self.assertIn("unreadable bench artifact", err.getvalue())

    def test_missing_file_fails(self):
        out, err = io.StringIO(), io.StringIO()
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            code = check_bench.main(["check_bench.py", "/nonexistent/BENCH_serve.json"])
        self.assertEqual(code, 1)
        self.assertIn("unreadable bench artifact", err.getvalue())

    def test_toeplitz_regressed_speedup_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_toeplitz.json"]["toeplitz"]["mvm_speedup_ge_2x"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("REGRESSED", err)
        self.assertIn("toeplitz.mvm_speedup_ge_2x", err)

    def test_toeplitz_thread_divergence_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_toeplitz.json"]["toeplitz"]["bit_identical_threads"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("toeplitz.bit_identical_threads", err)

    def test_toeplitz_missing_speedup_number_fails(self):
        docs = synthetic_artifacts()
        del docs["BENCH_toeplitz.json"]["toeplitz"]["mvm_speedup"]
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("mvm_speedup", err)

    def test_ski_regressed_rmse_gate_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_ski.json"]["ski"]["rmse_within_5pct_of_dense"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("REGRESSED", err)
        self.assertIn("ski.rmse_within_5pct_of_dense", err)

    def test_ski_regressed_speedup_gate_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_ski.json"]["ski"]["fit_speedup_ge_2x"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("ski.fit_speedup_ge_2x", err)

    def test_ski_thread_divergence_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_ski.json"]["ski"]["bit_identical_threads"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("ski.bit_identical_threads", err)

    def test_ski_missing_rmse_number_fails(self):
        docs = synthetic_artifacts()
        del docs["BENCH_ski.json"]["ski"]["rmse_ski"]
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("rmse_ski", err)

    def test_fit_rows_must_exist(self):
        docs = synthetic_artifacts()
        del docs["BENCH_par.json"]["fit"]
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("'fit' rows missing or empty", err)

    def test_fit_row_not_bit_identical_fails(self):
        docs = synthetic_artifacts()
        docs["BENCH_par.json"]["fit"][1]["bit_identical"] = False
        code, _, err = run_main(docs)
        self.assertEqual(code, 1)
        self.assertIn("threads=4", err)
        self.assertIn("not bit-identical", err)

    def test_one_bad_artifact_fails_the_whole_run(self):
        docs = synthetic_artifacts()
        docs["BENCH_precision.json"]["fig3_accuracy"]["within_1pct"] = False
        code, out, err = run_main(docs)
        self.assertEqual(code, 1)
        # the healthy artifacts still print their ok lines first
        self.assertIn("ok", out)
        self.assertIn("within_1pct", err)

    def test_no_arguments_prints_usage_and_exits_two(self):
        out, err = io.StringIO(), io.StringIO()
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            code = check_bench.main(["check_bench.py"])
        self.assertEqual(code, 2)
        self.assertIn("Usage", out.getvalue())

    def test_gate_registry_and_docstring_agree(self):
        # every gated basename should be named in the module docstring,
        # so the operator-facing documentation cannot silently drift
        for base in list(check_bench.GATES) + list(check_bench.REQUIRED_NUMBERS):
            self.assertIn(base, check_bench.__doc__, base)


if __name__ == "__main__":
    unittest.main(verbosity=2)
